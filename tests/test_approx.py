"""Statistical verification of the approximate-query ladder (PR 10 tentpole).

Layers:

  * **Sampling** — stratified selection invariants: per-stratum floor of one,
    ceil(n/den) rates, rung nesting (the same row ranks identically at every
    den), determinism in the seed, bookkeeping columns, cache + invalidation
    through the planner registry.
  * **Estimators** — Student-t/normal critical values, honesty gates
    (m < 2 -> infinite half-width, fully-sampled -> zero width).
  * **Monte-Carlo coverage** — the ISSUE gate: for each estimable aggregate
    kind (sum / count / avg) and each sampling rung (1/16..1/2), >= 200
    seeded trials with the true answer inside the reported 95 % CI at
    >= 90 % empirical rate.  Binomial slack: at true coverage 0.95 the
    empirical rate over 200 trials has sd sqrt(.95*.05/200) ~= 1.5 %, so a
    0.90 gate sits > 3 sigma below nominal — a pass is evidence, not luck.
    The 20-trial smoke (tier-1) has sd ~= 4.9 %; its 0.80 gate is the same
    3-sigma slack.  Everything is pinned to ``conftest.APPROX_SEED`` so the
    asserted rates are deterministic numbers, not flaky draws.
  * **Rung-1 identity** — the den == 1 rewrite is a pure scan rename; its
    results are byte-identical to the exact plan on both planner legs and
    both wire formats (the differential leg).
  * **Refusal** — min/max, semi-join-dependent counts, estimates folded into
    scalar arithmetic, grouped estimates feeding a filter/join (q18, SQL
    HAVING), tiny tables: the rewrite returns None and the progressive
    runner falls back to the exact plan (rung 0).  A Select between the site
    and the root keeps the moment columns flowing; finalize raises if a
    scaled result ever arrives without them.
  * **Progressive** — hypothesis property: termination with a final interval
    within tolerance (or the exact top rung), escalations audited as
    TOLERANCE_MISS attempts; the adversarial absent-group case must climb to
    exact rather than fabricate zeros.
  * **Surfacing** — per-rung AttemptReports render rung + CI width in the
    ``--section runs`` audit table; ``QueryServer.submit(tolerance=)`` serves
    off the ladder with rung-keyed cache entries.
"""
import json
import os

import numpy as np
import pytest

from repro.core import backend as B
from repro.core import plan as P
from repro.core import planner
from repro.core.plan import col, scan
from repro.core.table import Database
from repro.data import tpch
from repro.queries import QUERIES
from repro.approx import estimators, progressive, sampling
from repro.approx import rewrite as approx_rewrite
from repro.approx.rewrite import rewrite_for_rung

from conftest import APPROX_SEED

pytestmark = pytest.mark.approx

SMOKE_TRIALS = 20     # tier-1 smoke; the slow sweep runs the full 200
FULL_TRIALS = 200
DENS = (16, 8, 4, 2)  # rung 1 is exact by construction — tested for identity


@pytest.fixture(scope="module")
def db():
    return tpch.generate(0.005, seed=11)


# ---------------------------------------------------------------------------
# sampling invariants
# ---------------------------------------------------------------------------

def test_selection_rates_and_min_one():
    rng = np.random.default_rng(APPROX_SEED)
    g = rng.integers(0, 12, size=3000).astype(np.int64)
    for den in DENS:
        mask, sid, n_g, m_g = sampling.stratified_selection([g], g.size, den)
        np.testing.assert_array_equal(m_g, np.maximum(1, -(-n_g // den)))
        got = np.bincount(sid[mask], minlength=n_g.size)
        np.testing.assert_array_equal(got, m_g)   # exactly m_g rows kept
    # a 1-row stratum survives every rung (floor of one)
    tiny = np.array([0, 1, 1, 1, 1], dtype=np.int64)
    mask, _, n_g, m_g = sampling.stratified_selection([tiny], 5, 16)
    assert m_g[0] == 1 and mask[0]


def test_rungs_nest():
    """A row sampled at rung 1/d stays sampled at every larger rung."""
    rng = np.random.default_rng(APPROX_SEED + 1)
    g = rng.integers(0, 7, size=2000).astype(np.int64)
    masks = {den: sampling.stratified_selection([g], g.size, den)[0]
             for den in (16, 8, 4, 2, 1)}
    assert masks[1].all()
    for small, big in ((16, 8), (8, 4), (4, 2), (2, 1)):
        assert not np.any(masks[small] & ~masks[big])


def test_selection_deterministic_in_seed():
    g = np.zeros(1000, dtype=np.int64)
    a = sampling.stratified_selection([g], 1000, 4, seed=7)[0]
    b = sampling.stratified_selection([g], 1000, 4, seed=7)[0]
    c = sampling.stratified_selection([g], 1000, 4, seed=8)[0]
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_sample_table_bookkeeping():
    rng = np.random.default_rng(APPROX_SEED + 2)
    g = rng.integers(0, 9, size=1500).astype(np.int64)
    cols = {"g": g, "v": rng.normal(size=g.size)}
    s = sampling.sample_table(cols, ("g",), 4)
    n_g = np.bincount(g)
    m_g = np.maximum(1, -(-n_g // 4))
    np.testing.assert_array_equal(s["__sn"], n_g[s["g"]])
    np.testing.assert_array_equal(s["__sm"], m_g[s["g"]])
    np.testing.assert_allclose(s["__sw"],
                               n_g[s["g"]] / m_g[s["g"]], rtol=0)
    assert s["__sw"].dtype == np.float64
    # row order of the base table is preserved (the mask is order-stable)
    mask = sampling.stratified_selection([g], g.size, 4)[0]
    np.testing.assert_array_equal(s["v"], cols["v"][mask])


def test_sample_table_empty_strata():
    """Zero-row tables sample to zero rows — no crash, no fabricated rows."""
    cols = {"g": np.zeros(0, dtype=np.int64), "v": np.zeros(0)}
    s = sampling.sample_table(cols, ("g",), 8)
    assert s["g"].size == 0 and s["__sw"].size == 0


def test_rung_database_cached_and_invalidated():
    rng = np.random.default_rng(APPROX_SEED + 3)
    db2 = Database(tables={"facts": {
        "g": rng.integers(0, 5, 400).astype(np.int64),
        "v": rng.normal(size=400)}}, dicts={}, scale=1.0)
    r1 = sampling.rung_database(db2, "facts", ("g",), 4)
    assert sampling.rung_database(db2, "facts", ("g",), 4) is r1
    assert sampling.rung_name("facts", 4) in r1.tables
    # the rung partitions like its base table
    assert B.PARTITION_KEYS.get(sampling.rung_name("facts", 4)) == \
        B.PARTITION_KEYS.get("facts")
    planner.invalidate_stats(db2)   # the documented mutation protocol
    assert sampling.rung_database(db2, "facts", ("g",), 4) is not r1
    sampling.invalidate(db2)


def test_rung_partition_key_hygiene():
    """An unpartitioned base table must not leave a name -> None mapping in
    the global PARTITION_KEYS (dryrun analytics would read it as replicated),
    and a registered rung entry is dropped with its invalidated rung."""
    rng = np.random.default_rng(APPROX_SEED + 4)
    db2 = Database(tables={"facts": {
        "g": rng.integers(0, 5, 400).astype(np.int64),
        "v": rng.normal(size=400)}}, dicts={}, scale=1.0)
    name = sampling.rung_name("facts", 8)
    try:
        sampling.rung_database(db2, "facts", ("g",), 8)
        assert name not in B.PARTITION_KEYS   # no explicit None entry
        # a partitioned base registers its key, invalidation unregisters it
        sampling.invalidate(db2)
        B.PARTITION_KEYS["facts"] = "g"
        sampling.rung_database(db2, "facts", ("g",), 8)
        assert B.PARTITION_KEYS[name] == "g"
        planner.invalidate_stats(db2)
        assert name not in B.PARTITION_KEYS
    finally:
        B.PARTITION_KEYS.pop("facts", None)
        B.PARTITION_KEYS.pop(name, None)
        sampling.invalidate(db2)


# ---------------------------------------------------------------------------
# estimator unit behavior
# ---------------------------------------------------------------------------

def test_t_value_table_and_normal_limit():
    assert float(estimators.t_value(1)) == pytest.approx(12.706)
    assert float(estimators.t_value(10)) == pytest.approx(2.228)
    assert float(estimators.t_value(31)) == pytest.approx(
        estimators.z_value(0.95))
    df = np.array([1, 2, 5, 30, 100])
    t = estimators.t_value(df)
    assert np.all(np.diff(t) < 0)   # monotone toward the normal quantile


def test_z_value_bisection_fallback():
    # untabulated confidence: scipy-free erf inversion
    assert estimators.z_value(0.975) == pytest.approx(2.241402728, abs=1e-6)


def test_interval_honesty_gates():
    # m < 2: no variance estimate — infinite half-width
    _, hw = estimators.interval("sum", n=100, m=1, mf=1, s1=5.0, s2=25.0)
    assert np.isinf(hw)
    # fully sampled: exact — zero half-width
    _, hw = estimators.interval("sum", n=10, m=10, mf=4, s1=5.0, s2=25.0)
    assert float(hw) == 0.0
    # avg with a single post-filter row: infinite
    _, hw = estimators.interval("avg", n=100, m=8, mf=1, s1=5.0, s2=25.0)
    assert np.isinf(hw)


def test_non_estimable_ops_raise():
    with pytest.raises(ValueError):
        estimators.interval("min", 10, 5, 5, 1.0, 1.0)
    with pytest.raises(ValueError):
        estimators.point_estimate("max", 10, 5, 5, 1.0)


def test_finalize_raises_on_dropped_moments():
    """The tolerance guarantee's last line of defense: a scale-rewritten
    result whose __ap_* moments were projected away must raise, never be
    served as an exact zero-width answer."""
    with pytest.raises(ValueError, match="moment"):
        estimators.finalize_result({"s": np.array([7.0])},
                                   (("s", "sum"),), scaled=True)
    # scaled target present but its own s1/s2 moments missing
    with pytest.raises(ValueError, match="s1"):
        estimators.finalize_result(
            {"s": np.array([7.0]),
             estimators.N_COL: np.array([16]),
             estimators.M_COL: np.array([4]),
             estimators.MF_COL: np.array([4])},
            (("s", "sum"),), scaled=True)
    # unscaled (rung-1 / refused) results still pass through exact
    est = estimators.finalize_result({"s": np.array([7.0])},
                                     (("s", "sum"),), scaled=False)
    assert est.exact and est.rel_width == 0.0


# ---------------------------------------------------------------------------
# Monte-Carlo coverage: the statistical gate
# ---------------------------------------------------------------------------

def _scalar_coverage(op: str, den: int, trials: int, seed: int) -> float:
    """Empirical CI coverage for one op x rung on random skewed populations.

    Single global stratum, gamma(2, 10) values, a random filter at the
    0.2-0.6 quantile: the same moments the plan rewrite injects, computed
    directly so the gate isolates the estimator math.
    """
    rng = np.random.default_rng(seed)
    hits = 0
    for _ in range(trials):
        n = int(rng.integers(400, 2000))
        v = rng.gamma(2.0, 10.0, size=n)
        keep = v > np.quantile(v, rng.uniform(0.2, 0.6))
        mask, _, _, m_g = sampling.stratified_selection(
            [], n, den, seed=int(rng.integers(1 << 31)))
        m = int(m_g[0])
        sv, sk = v[mask], keep[mask]
        mf = int(sk.sum())
        if op == "avg":
            xs = sv[sk]
            s1, s2 = float(xs.sum()), float((xs * xs).sum())
            truth = float(v[keep].mean()) if keep.any() else np.nan
        else:
            x = np.where(sk, sv, 0.0)
            s1, s2 = float(x.sum()), float((x * x).sum())
            truth = float(v[keep].sum()) if op == "sum" else float(keep.sum())
        est, hw = estimators.interval(op, n, m, mf, s1, s2)
        if np.isinf(float(hw)) or (truth == truth and
                                   abs(truth - float(est)) <= float(hw)):
            hits += 1
    return hits / trials


@pytest.mark.parametrize("op", sorted(estimators.ESTIMABLE_OPS))
@pytest.mark.parametrize("den", DENS)
def test_coverage_smoke(op, den, approx_seed):
    """Tier-1 smoke: 20 trials per combo.  Gate 0.80 == nominal 0.95 minus
    3 sigma of binomial noise at 20 trials (deterministic at APPROX_SEED;
    the observed minimum across all combos is exactly 0.80)."""
    cov = _scalar_coverage(op, den, SMOKE_TRIALS, approx_seed + den)
    assert cov >= 0.80, f"{op} 1/{den}: coverage {cov}"


@pytest.mark.slow
@pytest.mark.parametrize("op", sorted(estimators.ESTIMABLE_OPS))
@pytest.mark.parametrize("den", DENS)
def test_coverage_full(op, den, approx_seed):
    """The ISSUE gate: >= 200 seeded trials, truth inside the 95 % CI at
    >= 90 % empirical rate for every estimable op x rung.  Observed rates at
    APPROX_SEED are 0.925-0.985."""
    cov = _scalar_coverage(op, den, FULL_TRIALS, approx_seed + den)
    assert cov >= 0.90, f"{op} 1/{den}: coverage {cov}"


def _group_coverage(op: str, den: int, trials: int, seed: int) -> float:
    """Group-level coverage through ``sample_table`` with 10 strata of
    wildly uneven sizes (4..400) — the small-m regime the t correction is
    for."""
    rng = np.random.default_rng(seed)
    hits = total = 0
    for _ in range(trials):
        sizes = rng.integers(4, 400, size=10)
        g = np.repeat(np.arange(10), sizes)
        v = rng.gamma(2.0, 10.0, size=g.size)
        samp = sampling.sample_table(
            {"g": g.astype(np.int64), "v": v}, ("g",), den,
            seed=int(rng.integers(1 << 31)))
        thr = np.quantile(v, 0.3)
        for gi in range(10):
            gm = samp["g"] == gi
            n, m = int(samp["__sn"][gm][0]), int(samp["__sm"][gm][0])
            sv = samp["v"][gm]
            sk = sv > thr
            mf = int(sk.sum())
            pop = v[g == gi]
            popk = pop > thr
            if op == "avg":
                xs = sv[sk]
                s1, s2 = float(xs.sum()), float((xs * xs).sum())
                truth = float(pop[popk].mean()) if popk.any() else np.nan
            else:
                x = np.where(sk, sv, 0.0)
                s1, s2 = float(x.sum()), float((x * x).sum())
                truth = (float(pop[popk].sum()) if op == "sum"
                         else float(popk.sum()))
            est, hw = estimators.interval(op, n, m, mf, s1, s2)
            total += 1
            if np.isinf(float(hw)) or (truth == truth and
                                       abs(truth - float(est)) <= float(hw)):
                hits += 1
    return hits / total


@pytest.mark.parametrize("op", sorted(estimators.ESTIMABLE_OPS))
@pytest.mark.parametrize("den", DENS)
def test_group_coverage(op, den, approx_seed):
    """200 group-observations (20 trials x 10 strata) per combo.  Gate 0.85:
    observations within a trial share one selection draw, so the effective
    sample is smaller than 200 — the observed minimum at APPROX_SEED is 0.88
    (count, 1/16); with the z-quantile instead of Student-t it was 0.843,
    which is what forced the t correction in ``estimators``."""
    cov = _group_coverage(op, den, SMOKE_TRIALS,
                          (approx_seed + den) ^ 0xABCDEF)
    assert cov >= 0.85, f"{op} 1/{den}: group coverage {cov}"


def test_plan_level_coverage_q1(db, approx_seed):
    """End-to-end: the rewritten q1 plan's per-group error bars cover the
    exact answers across 10 sampling seeds at rung 1/8 (>= 90 %)."""
    exact, _ = B.run_reference(QUERIES[1], db)
    keys = ("l_returnflag", "l_linestatus")
    exact_by_key = {tuple(int(exact[k][i]) for k in keys): i
                    for i in range(exact[keys[0]].size)}
    hits = total = 0
    for s in range(10):
        rw = rewrite_for_rung(QUERIES[1], db, 8, seed=approx_seed + s)
        cols, _ = B.run_reference(rw.query, rw.db)
        est = rw.finalize(cols)
        for name, _op in rw.targets:
            hw = est.half_width[name]
            for i in range(est.result[keys[0]].size):
                j = exact_by_key[tuple(int(est.result[k][i]) for k in keys)]
                total += 1
                if np.isinf(hw[i]) or \
                        abs(float(exact[name][j]) -
                            float(est.result[name][i])) <= float(hw[i]):
                    hits += 1
    assert total >= 10 * 4 * len(rw.targets) // 2
    assert hits / total >= 0.90, f"plan-level coverage {hits / total}"


# ---------------------------------------------------------------------------
# rung-1 differential identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wire", [None, "wide"])
@pytest.mark.parametrize("infer", [True, False])
@pytest.mark.parametrize("qid", [1, 6, 18])
def test_rung1_byte_identity(db, qid, infer, wire):
    """den == 1 is a pure scan rename: byte-identical to the exact plan on
    both planner legs (inference on/off == REPRO_PLANNER=1/0) and both wire
    formats."""
    rw = rewrite_for_rung(QUERIES[qid], db, 1)
    assert rw is not None and rw.den == 1
    exact, _ = B.run_local(QUERIES[qid].with_inference(infer), db,
                           jit=False, wire_format=wire)
    got, _ = B.run_local(rw.query.with_inference(infer), rw.db,
                         jit=False, wire_format=wire)
    assert set(exact) == set(got)
    for k in exact:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(exact[k]), err_msg=k)
    assert rw.finalize(got).exact


def test_rung1_byte_identity_jitted(db):
    """One jitted leg to pin the compiled path too."""
    rw = rewrite_for_rung(QUERIES[6], db, 1)
    exact, _ = B.run_local(QUERIES[6], db, jit=True, wire_format="wide")
    got, _ = B.run_local(rw.query, rw.db, jit=True, wire_format="wide")
    for k in exact:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(exact[k]), err_msg=k)


# ---------------------------------------------------------------------------
# refusal: the honest "run exact" answers
# ---------------------------------------------------------------------------

def _synth_db(rows=512, groups=8, seed=0):
    rng = np.random.default_rng(APPROX_SEED + seed)
    return Database(tables={"facts": {
        "g": rng.integers(0, groups, rows).astype(np.int64),
        "v": rng.normal(size=rows)}}, dicts={}, scale=1.0)


def test_refuses_min_max(db):
    db2 = _synth_db()
    q = planner.compile_query(lambda: scan("facts").group_by(
        ["g"], [("mx", "max", "v")], exchange="gather", final=True),
        name="minmax")
    assert rewrite_for_rung(q, db2, 4, tables=("facts",)) is None
    # TPC-H shapes with min at the site refuse too
    assert rewrite_for_rung(QUERIES[2], db, 4) is None


def test_refuses_semi_join_counts(db):
    """q4's count is semi-join-dependent: no per-stratum weight scales it."""
    assert rewrite_for_rung(QUERIES[4], db, 4) is None


def test_refuses_tiny_table():
    db2 = _synth_db(rows=100)
    q = planner.compile_query(lambda: scan("facts").group_by(
        ["g"], [("s", "sum", "v")], exchange="gather", final=True),
        name="tiny")
    assert rewrite_for_rung(q, db2, 4, tables=("facts",)) is None
    assert rewrite_for_rung(q, db2, 4, tables=("facts",),
                            min_rows=10) is not None


def test_refuses_group_estimate_feeding_computation(db):
    """A GroupBy site's scaled estimates may only reach the root through
    projections and Finalize.  q18's grouped sum feeds a HAVING-style filter
    and two joins — group membership decided by an un-barred estimate — so
    every sampled rung refuses (rung 1 stays a pure rename, tested above)."""
    for den in (16, 8, 4, 2):
        assert rewrite_for_rung(QUERIES[18], db, den) is None
    # synthetic minimal shape: Filter directly on the aggregate output
    db2 = _synth_db()
    q = planner.compile_query(lambda: scan("facts").group_by(
        ["g"], [("s", "sum", "v")], exchange="gather", final=True)
        .filter(col("s") > 0.0).finalize(replicated=True), name="having")
    assert rewrite_for_rung(q, db2, 4, tables=("facts",)) is None
    # SQL HAVING lowers to exactly that Filter
    from repro.sql import compile_sql
    qs = compile_sql("SELECT l_returnflag, sum(l_quantity) AS sq "
                     "FROM lineitem GROUP BY l_returnflag "
                     "HAVING sum(l_quantity) > 100", name="having_sql")
    assert rewrite_for_rung(qs, db, 4) is None


def test_select_above_site_keeps_moments(db):
    """SQL lowering emits a Select above the GroupBy whenever the SELECT
    list reorders or omits outputs (lower.py); the rewrite must extend that
    projection so the moment columns reach finalize — this was the silent
    width-0.0 bug: a den=16 HT estimate served as exact."""
    from repro.sql import compile_sql
    q = compile_sql("SELECT sum(l_quantity) AS sq, l_returnflag "
                    "FROM lineitem GROUP BY l_returnflag "
                    "ORDER BY l_returnflag", name="reorder")
    for den in (16, 4):
        rw = rewrite_for_rung(q, db, den)
        assert rw is not None
        cols, _ = B.run_reference(rw.query, rw.db)
        assert estimators.N_COL in cols        # moments survived the Select
        est = rw.finalize(cols)
        assert 0.0 < est.rel_width < np.inf    # honest bars, not fake-exact
        assert estimators.N_COL not in est.result
    # plan-level: the projection may also drop a target — it is then simply
    # not served, while the surviving target keeps its bars
    db2 = _synth_db(rows=2048)
    qp = planner.compile_query(lambda: scan("facts").group_by(
        ["g"], [("s", "sum", "v"), ("c", "count", None)],
        exchange="gather", final=True).select("s", "g")
        .finalize(sort_keys=[("g", True)], replicated=True), name="proj")
    rw = rewrite_for_rung(qp, db2, 4, tables=("facts",))
    cols, _ = B.run_reference(rw.query, rw.db)
    est = rw.finalize(cols)
    assert "c" not in est.result and "s" in est.half_width
    assert est.rel_width > 0.0


def test_refuses_estimate_in_scalar_arithmetic():
    """A scalar estimate folded into arithmetic has no attachable bar."""
    db2 = _synth_db()
    base = scan("facts")
    agg = base.agg_scalar([("s", "sum", "v"), ("c", "count", None)])
    q = planner.compile_query(
        lambda: P.ScalarResult({"ratio": P.ScalarRef(agg, "s") /
                                P.ScalarRef(agg, "c")}), name="ratio")
    assert rewrite_for_rung(q, db2, 4, tables=("facts",)) is None


def test_progressive_rejects_off_ladder_rung(db):
    """A custom ladder with a denominator the sampler has no rung for must
    fail at construction, not blow up mid-run()."""
    with pytest.raises(ValueError, match="sampling ladder"):
        progressive.ProgressiveRunner(db, ladder=(32, 16, 1))
    # valid subsets of the sampling ladder are still accepted
    r = progressive.ProgressiveRunner(db, ladder=(16, 4, 1))
    assert r.ladder == (16, 4, 1)


def test_progressive_exact_fallback(db):
    runner = progressive.ProgressiveRunner(db, tolerance=0.5,
                                           local_jit=False)
    ans = runner.run(QUERIES[4])
    assert ans.rung == 0 and ans.exact and ans.ci_width == 0.0
    exact, _ = B.run_reference(QUERIES[4], db)
    for k in exact:
        np.testing.assert_array_equal(np.asarray(ans.result[k]),
                                      np.asarray(exact[k]))
    assert ans.report.attempts[-1].rung == 0


# ---------------------------------------------------------------------------
# progressive escalation
# ---------------------------------------------------------------------------

def test_absent_group_escalates_never_fabricates(db):
    """Adversarial: one qualifying row per group.  Small rungs mostly miss
    it; any group they do emit must be a genuine (weighted) observation —
    never a fabricated zero — and the ladder must climb to the exact rung."""
    rng = np.random.default_rng(APPROX_SEED + 9)
    g = np.repeat(np.arange(8), 64).astype(np.int64)
    v = np.tile(np.arange(64), 8).astype(np.int64)
    perm = rng.permutation(g.size)             # scramble rows, keep pairing
    db2 = Database(tables={"facts": {"g": g[perm], "v": v[perm]}},
                   dicts={}, scale=1.0)

    def build():
        return scan("facts").filter(col("v") > 62).group_by(
            ["g"], [("c", "count", None), ("s", "sum", "v")],
            exchange="gather", final=True) \
            .finalize(sort_keys=[("g", True)], replicated=True)

    q = planner.compile_query(build, name="needle")
    # direct look at a small rung: groups may be absent, never zero
    rw = rewrite_for_rung(q, db2, 4, tables=("facts",))
    cols, _ = B.run_reference(rw.query, rw.db)
    assert cols["g"].size <= 8
    assert np.all(np.asarray(cols["c"], np.float64) > 0)
    assert np.all(np.asarray(cols["s"], np.float64) > 0)
    # the ladder ends at the exact full-table rung
    runner = progressive.ProgressiveRunner(db2, tolerance=0.05,
                                           tables=("facts",),
                                           local_jit=False)
    ans = runner.run(q)
    assert ans.rung == 1 and ans.exact
    np.testing.assert_array_equal(ans.result["g"], np.arange(8))
    np.testing.assert_array_equal(np.asarray(ans.result["c"], np.int64),
                                  np.ones(8, np.int64))
    np.testing.assert_array_equal(np.asarray(ans.result["s"], np.int64),
                                  np.full(8, 63))
    assert ans.escalations == len(ans.report.attempts) - 1


def test_progressive_termination_property(db):
    """Hypothesis property: for any tolerance the runner terminates with a
    final interval within tolerance or the exact top rung; every climb is an
    audited TOLERANCE_MISS whose measured width exceeded the tolerance.
    Falls back to a seeded log-uniform sweep when hypothesis is absent (the
    image does not ship it; the CI approx job runs the real property)."""
    def prop(tol):
        runner = progressive.ProgressiveRunner(db, tolerance=tol,
                                               local_jit=False)
        ans = runner.run(QUERIES[6])
        rungs = [a.rung for a in ans.report.attempts]
        assert rungs == sorted(rungs, reverse=True)   # climbs monotonically
        assert ans.rung >= 1                          # q6 is estimable
        assert ans.ci_width <= tol or ans.rung == 1
        for a in ans.report.attempts[:-1]:
            assert a.outcome == "tolerance_miss"
            assert a.ci_width > tol
        assert ans.report.attempts[-1].outcome == "ok"
        assert ans.escalations == len(ans.report.attempts) - 1

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        rng = np.random.default_rng(APPROX_SEED)
        for tol in 10.0 ** rng.uniform(-4.0, 1.0, size=6):
            prop(float(tol))
        return
    settings(max_examples=8, deadline=None, derandomize=True)(
        given(tol=st.floats(min_value=1e-4, max_value=10.0,
                            allow_nan=False, allow_infinity=False))(prop))()


def test_progressive_rung1_is_exact(db):
    """tolerance=0 forces the whole ladder; the top rung answers exactly."""
    runner = progressive.ProgressiveRunner(db, tolerance=0.0,
                                           local_jit=False)
    ans = runner.run(QUERIES[6])
    assert ans.rung == 1 and ans.exact and ans.ci_width == 0.0
    exact, _ = B.run_reference(QUERIES[6], db)
    np.testing.assert_array_equal(np.asarray(ans.result["revenue"]),
                                  np.asarray(exact["revenue"]))
    assert [a.rung for a in ans.report.attempts] == [16, 8, 4, 2, 1]


# ---------------------------------------------------------------------------
# surfacing: audit table + serving
# ---------------------------------------------------------------------------

def test_run_report_renders_rung_and_ci(db, capsys):
    from repro.launch import report as rep
    runner = progressive.ProgressiveRunner(db, tolerance=0.0,
                                           local_jit=False)
    ans = runner.run(QUERIES[6])
    rec = rep.run_report_record("q6", ans.report)
    rec = json.loads(json.dumps(rec))          # must stay JSON-able
    fallback = progressive.ProgressiveRunner(db, tolerance=0.5,
                                             local_jit=False).run(QUERIES[4])
    rec2 = json.loads(json.dumps(rep.run_report_record("q4",
                                                       fallback.report)))
    rep.run_report_table([rec, rec2])
    out = capsys.readouterr().out
    assert "| rung | ci |" in out
    for den in (16, 8, 4, 2):
        assert f"| 1/{den} |" in out
    assert "| 1/1 | 0.00% |" in out            # the exact top rung
    assert "| exact |" in out                  # q4's rung-0 fallback
    # climbed rungs are tolerance_miss rows with a percentage ci cell
    miss = [ln for ln in out.splitlines() if "tolerance_miss" in ln]
    assert len(miss) == 4 and all("%" in ln for ln in miss)


def test_serve_tolerance_path(db):
    from repro import serve
    srv = serve.QueryServer(db)
    r = srv.submit(6, tolerance=0.5)
    assert srv.approx_served == 1 and srv.approx_escalations == 0
    assert r["revenue"].size == 1
    rc0, h0 = srv.recompiles, srv.cache_hits
    srv.submit(6, tolerance=0.5)               # rewrite + executable cached
    assert srv.recompiles == rc0 and srv.cache_hits >= h0 + 2
    # tolerance=0 climbs the whole ladder; rung 1 == exact, byte for byte
    approx = srv.submit(6, tolerance=0.0)
    exact = srv.submit(6)
    assert set(approx) == set(exact)
    for k in exact:
        np.testing.assert_array_equal(approx[k], exact[k])
    assert srv.approx_escalations == 4
    # a refused shape serves exact and says so
    r4 = srv.submit(4, tolerance=0.5)
    assert srv.approx_refused == 1
    exact4, _ = B.run_reference(QUERIES[4], db)
    np.testing.assert_array_equal(np.asarray(r4["order_count"]),
                                  np.asarray(exact4["order_count"]))


def test_approx_default_env(monkeypatch):
    monkeypatch.delenv("REPRO_APPROX", raising=False)
    assert progressive.approx_default() is None
    monkeypatch.setenv("REPRO_APPROX", "off")
    assert progressive.approx_default() is None
    monkeypatch.setenv("REPRO_APPROX", "0.25")
    assert progressive.approx_default() == 0.25


def test_serve_env_default_tolerance(db, monkeypatch):
    from repro import serve
    monkeypatch.setenv("REPRO_APPROX", "0.5")
    srv = serve.QueryServer(db)
    srv.submit(6)                              # no tolerance= needed
    assert srv.approx_served == 1

"""Pallas kernels: shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.flash_attention import ops as fa
from repro.kernels.hash_probe import ops as hp
from repro.kernels.hash_probe.ref import hash_probe_ref
from repro.kernels.radix_hist import ops as rh
from repro.kernels.segsum import ops as ss
from repro.kernels.segsum.ref import segment_sum_ref

rng = np.random.default_rng(42)


@pytest.mark.parametrize("n,g,c", [(64, 5, 1), (300, 17, 2), (1000, 50, 3),
                                   (2048, 130, 8), (4096, 200, 16)])
def test_segsum_sweep(n, g, c):
    gids = jnp.asarray(rng.integers(0, g, n).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=(n, c)).astype(np.float32))
    got = ss.segment_sum(gids, vals, g)
    want = segment_sum_ref(gids, vals, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=1e-4)


def test_segsum_1d_values():
    gids = jnp.asarray(rng.integers(0, 9, 100).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=100).astype(np.float32))
    got = ss.segment_sum(gids, vals, 9)
    assert got.shape == (9,)
    np.testing.assert_allclose(float(got.sum()), float(vals.sum()), rtol=1e-5)


@pytest.mark.parametrize("n,p,blk", [(100, 8, 64), (1000, 64, 256),
                                     (4096, 256, 512), (777, 13, 128)])
def test_radix_hist_sweep(n, p, blk):
    keys = jnp.asarray(rng.integers(0, 1 << 31, n).astype(np.int32))
    got = np.asarray(rh.radix_hist(keys, p, blk=blk))
    want = np.asarray(rh.radix_hist(keys, p, blk=blk, use_kernel=False))
    np.testing.assert_allclose(got.sum(axis=0), want.sum(axis=0))
    assert int(got.sum()) == n


@pytest.mark.parametrize("n,p,blk", [(7, 3, 64), (100, 8, 64),
                                     (1000, 9, 256), (4096, 17, 512),
                                     (5000, 129, 2048), (513, 2, 512)])
def test_counting_rank_fused_kernel_matches_oracle(n, p, blk):
    """The fused Pallas counting rank (histogram + triangular-matmul rank +
    on-chip running-total carry, ONE kernel) is byte-identical to the
    block-streamed jnp oracle — which itself matches a stable argsort."""
    keys = jnp.asarray(rng.integers(0, p, n).astype(np.int32))
    s_k, c_k = rh.counting_rank(keys, p, blk=blk, use_kernel=True,
                                interpret=True)
    s_o, c_o = rh.counting_rank(keys, p, blk=blk, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_o))
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_o))
    # oracle leg vs ground truth: rank within key == stable-sort position
    k = np.asarray(keys)
    truth = np.empty(n, np.int64)
    for part in range(p):
        truth[k == part] = np.arange(int((k == part).sum()))
    np.testing.assert_array_equal(np.asarray(s_o), truth)


def test_counting_rank_kernel_rank_independent_of_block_size():
    keys = jnp.asarray(rng.integers(0, 5, 700).astype(np.int32))
    base, cb = rh.counting_rank(keys, 5, blk=128, use_kernel=True,
                                interpret=True)
    for blk in (64, 256, 512):
        s, c = rh.counting_rank(keys, 5, blk=blk, use_kernel=True,
                                interpret=True)
        np.testing.assert_array_equal(np.asarray(s), np.asarray(base))
        np.testing.assert_array_equal(np.asarray(c), np.asarray(cb))


def test_skew_stats_detects_hot_partition():
    keys = jnp.asarray(np.concatenate([
        np.full(900, 12345, dtype=np.int32),
        rng.integers(0, 1 << 30, 100).astype(np.int32)]))
    stats = rh.skew_stats(keys, 16, blk=128)
    assert float(stats["imbalance"]) > 4.0


@pytest.mark.parametrize("m,n", [(10, 64), (100, 500), (1000, 3000)])
def test_hash_probe_sweep(m, n):
    bkeys = jnp.asarray(rng.choice(1 << 30, m, replace=False).astype(np.int32))
    bvals = jnp.arange(m, dtype=jnp.int32)
    pkeys = jnp.asarray(rng.integers(0, 1 << 30, n).astype(np.int32))
    got, cap = hp.hash_join_probe_auto(pkeys, bkeys, bvals)
    want = hash_probe_ref(pkeys, bkeys, bvals)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,hq,hkv,s,d,dt", [
    (1, 2, 1, 64, 32, np.float32),
    (2, 4, 4, 128, 64, np.float32),
    (1, 8, 2, 128, 128, np.float32),
    (2, 4, 2, 128, 64, np.float16),
])
def test_flash_attention_sweep(b, hq, hkv, s, d, dt):
    q = jnp.asarray(rng.normal(size=(b, hq, s, d)).astype(dt))
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)).astype(dt))
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)).astype(dt))
    got = fa.flash_attention(q, k, v, causal=True, q_blk=64, kv_blk=64)
    want = fa.flash_attention(q, k, v, causal=True, use_kernel=False)
    tol = 2e-3 if dt == np.float16 else 3e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_noncausal():
    q = jnp.asarray(rng.normal(size=(1, 2, 64, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 64, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 64, 32)).astype(np.float32))
    got = fa.flash_attention(q, k, v, causal=False, q_blk=32, kv_blk=32)
    want = fa.flash_attention(q, k, v, causal=False, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_segsum_property_conservation():
    pytest.importorskip("hypothesis")  # hypothesis is an optional dependency
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(st.integers(10, 300), st.integers(2, 40))
    def prop(n, g):
        gids = jnp.asarray(np.random.default_rng(n * g).integers(0, g, n)
                           .astype(np.int32))
        vals = jnp.asarray(np.random.default_rng(n + g).normal(size=(n, 1))
                           .astype(np.float32))
        got = ss.segment_sum(gids, vals, g)
        np.testing.assert_allclose(float(np.asarray(got).sum()),
                                   float(np.asarray(vals).sum()), atol=1e-3)

    prop()

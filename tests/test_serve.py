"""Serving-layer tests: templates, signatures, cache, batch, lineage keying.

Layers:

  * **Fingerprint** — the `plan_fingerprint` collision fix: content (columns,
    keys, aggs, literals, DAG wiring, parameter bindings) distinguishes
    plans the old type-name-sequence hash collided, and two bindings of one
    template can never exchange lineage snapshots.
  * **Templates** — domain-sound planner refinement (weakest bound over the
    parameter domain), bind-time domain validation, parameter-spec conflict
    detection.
  * **Cache/server** — one jit trace per template across bindings (the
    recompile gate's ground truth), FIFO bound, and eviction through the
    planner invalidation registry (`stats_override` entry/exit, table
    mutation).
  * **Batch** — the cross-query memo: a mixed interleaved parameterized
    stream through `BatchExecutor` is byte-identical to sequential
    one-query-at-a-time eager execution on both planner legs and both wire
    legs, with genuine cross-query sharing; an overflowing request re-runs
    conservatively without poisoning its neighbours.
"""
import numpy as np
import pytest

from repro import serve
from repro.core import backend as B
from repro.core import plan as P
from repro.core import planner
from repro.core.plan import col, param, scan
from repro.core.planner import (ColStats, params_of, plan_signature,
                                subplan_signatures)
from repro.core.table import days
from repro.data import tpch
from repro.distributed.lineage import LineageStore, plan_fingerprint
from repro.queries import QUERIES

FAST_QIDS = (1, 3, 5, 6, 9, 13, 18)


@pytest.fixture(scope="module")
def db():
    return tpch.generate(0.005, seed=11)


def _requests(qids):
    """Mixed interleaved parameterized stream: every sample of every qid,
    round-robin across queries (template changes request-to-request)."""
    per = [[(serve.TEMPLATES[q], s) for s in serve.TEMPLATES[q].samples]
           for q in qids]
    out, i = [], 0
    while any(per):
        if per[i % len(per)]:
            out.append(per[i % len(per)].pop(0))
        i += 1
    return out


# ---------------------------------------------------------------------------
# fingerprint: content, not shape
# ---------------------------------------------------------------------------

def _shape_twin_a():
    return scan("lineitem").filter(col("l_quantity") < 10) \
        .group_by(["l_returnflag"], [("s", "sum", "l_quantity")],
                  exchange="gather", final=True) \
        .finalize(sort_keys=[("l_returnflag", True)], replicated=True)


def _shape_twin_b():
    # IDENTICAL node-type sequence (Scan/Filter/GroupBy/Finalize) — the old
    # type-name-only fingerprint collided these two
    return scan("lineitem").filter(col("l_discount") < 10) \
        .group_by(["l_linestatus"], [("s", "sum", "l_extendedprice")],
                  exchange="gather", final=True) \
        .finalize(sort_keys=[("l_linestatus", True)], replicated=True)


def test_fingerprint_distinguishes_same_shaped_plans():
    a, b = planner.walk(_shape_twin_a()), planner.walk(_shape_twin_b())
    assert [type(n).__name__ for n in a] == [type(n).__name__ for n in b]
    assert plan_fingerprint(a) != plan_fingerprint(b)
    assert plan_signature(_shape_twin_a()) != plan_signature(_shape_twin_b())


def test_fingerprint_stable_across_rebuilds():
    # two independent builds of the SAME logical plan agree (the property
    # that lets a restarted process resume its own snapshots)
    assert plan_fingerprint(planner.walk(_shape_twin_a())) == \
        plan_fingerprint(planner.walk(_shape_twin_a()))


def test_fingerprint_distinguishes_bindings():
    t = serve.TEMPLATES[1]
    nodes = planner.walk(t.query.plan)
    b0 = t.bind().values
    b1 = t.bind(q1_cutoff=days("1998-08-15")).values
    assert plan_fingerprint(nodes, b0) != plan_fingerprint(nodes, b1)
    # canonical across host scalar types: numpy int == python int
    assert plan_fingerprint(nodes, {"q1_cutoff": np.int64(10448)}) == \
        plan_fingerprint(nodes, {"q1_cutoff": 10448})


def test_fingerprint_distinguishes_dag_sharing():
    # one subtree consumed twice (DAG) vs two equal-content copies (tree):
    # identical content per node, different wiring — walk ordinals differ,
    # so the signatures must too (snapshot tags are walk ordinals)
    def sel():
        return scan("orders").select("o_orderkey", "o_custkey")
    s = sel()
    dag = s.join(s, "o_custkey", "o_orderkey", ["o_orderkey"])
    tree = sel().join(sel(), "o_custkey", "o_orderkey", ["o_orderkey"])
    assert plan_signature(dag) != plan_signature(tree)


def test_bindings_never_exchange_snapshots(db, tmp_path):
    """Two bindings of one template run through one LineageStore directory:
    the second run must NOT resume from the first's snapshots."""
    from repro.distributed.lineage import run_resumable
    t = serve.TEMPLATES[1]
    store = LineageStore(str(tmp_path / "lineage"))
    r_a, _, overflow, reused_a = run_resumable(t.bind(), db, store)
    assert not overflow and reused_a == 0 and store.saved > 0
    # re-running the SAME binding resumes from its snapshots...
    _, _, _, reused_again = run_resumable(t.bind(), db, store)
    assert reused_again > 0
    # ...but a DIFFERENT binding of the same template, same store directory,
    # must miss every one of them and produce ITS answer, not binding A's
    bound_b = t.bind(q1_cutoff=days("1998-08-15"))
    r_b, _, _, reused_b = run_resumable(bound_b, db, store)
    assert reused_b == 0, "cross-binding snapshot reuse: silent wrong answer"
    ref_b, _ = B.run_local(bound_b, db, jit=False)
    for k in ref_b:
        assert np.array_equal(ref_b[k], r_b[k])
    assert not np.array_equal(r_a["count_order"], r_b["count_order"])


# ---------------------------------------------------------------------------
# templates: domain-sound refinement + bind validation
# ---------------------------------------------------------------------------

def test_refinement_uses_weakest_domain_bound(db):
    sch = {"x": ColStats(0, 100, 101)}
    p = param("p", lo=10, hi=20)
    le = planner._refine_filter(col("x") <= p, sch, db)["x"]
    assert (le.lo, le.hi) == (0, 20)     # <= keeps rows up to the domain hi
    ge = planner._refine_filter(col("x") >= p, sch, db)["x"]
    assert (ge.lo, ge.hi) == (10, 100)   # >= keeps rows down to the domain lo
    eq = planner._refine_filter(col("x") == p, sch, db)["x"]
    assert (eq.lo, eq.hi, eq.card) == (10, 20, 11)
    # a domainless parameter refines nothing (conservative, always sound)
    free = planner._refine_filter(col("x") <= param("q"), sch, db)["x"]
    assert (free.lo, free.hi) == (0, 100)
    # a literal still refines exactly as before
    lit = planner._refine_filter(col("x") <= 42, sch, db)["x"]
    assert lit.hi == 42


def test_template_info_sound_for_every_binding(db):
    """One cached PlanInfo serves every binding: claims derived from the
    parameter DOMAINS must hold at the extreme admissible bindings — with
    inference on, the extremes run without overflow (``run_local`` asserts
    it) and match the no-hints execution exactly."""
    t = serve.TEMPLATES[1]
    lo_dom, hi_dom = t.params["q1_cutoff"].lo, t.params["q1_cutoff"].hi
    for cutoff in (lo_dom, hi_dom):
        bound = t.bind(q1_cutoff=cutoff)
        got, _ = B.run_local(bound.with_inference(True), db, jit=False)
        ref, _ = B.run_local(bound.with_inference(False), db, jit=False)
        for k in ref:
            assert np.array_equal(ref[k], got[k]), (cutoff, k)


def test_bind_validation():
    t = serve.TEMPLATES[6]
    with pytest.raises(ValueError, match="unknown parameter"):
        t.bind(nope=3)
    with pytest.raises(ValueError, match="outside its declared domain"):
        t.bind(q6_qty=50)
    with pytest.raises(ValueError, match="int64"):
        t.bind(q6_qty=24.5)
    with pytest.raises(ValueError, match="expected a number"):
        t.bind(q6_qty="24")
    # dtype coercion: integral float binds an int64 param
    assert t.bind(q6_qty=24.0).values["q6_qty"] == 24
    # missing + no default
    bare = serve.PlanTemplate(
        lambda: scan("lineitem").filter(col("l_quantity") < param("k"))
        .agg_scalar([("n", "count", None)]), name="bare")
    with pytest.raises(ValueError, match="no binding and no default"):
        bare.bind()


def test_param_spec_conflict_detected():
    a = param("k", lo=0, hi=10)
    b = param("k", lo=0, hi=99)
    plan = scan("lineitem").filter((col("l_quantity") < a) &
                                   (col("l_linenumber") < b)) \
        .agg_scalar([("n", "count", None)])
    with pytest.raises(ValueError, match="conflicting declarations"):
        params_of(plan)


def test_param_domain_validation():
    with pytest.raises(ValueError, match="both lo and hi"):
        param("p", lo=3)
    with pytest.raises(ValueError, match="empty domain"):
        param("p", lo=5, hi=4)
    with pytest.raises(ValueError, match="unsupported dtype"):
        param("p", dtype="int32")
    assert param("p", lo=0.0, hi=1.0).dtype == "float64"
    assert param("p", lo=0, hi=1).dtype == "int64"


def test_subplan_signatures_content_addressed():
    # the same logical subtree built twice hashes alike (what batch sharing
    # keys on); parameter reachability is per-subtree
    t = serve.TEMPLATES[6]
    subs = subplan_signatures(t.query.plan)
    assert subs[id(t.query.plan)][1] == frozenset(t.params)
    scans = [n for n in planner.walk(t.query.plan)
             if isinstance(n, P.Scan)]
    assert all(subs[id(s)][1] == frozenset() for s in scans)
    twin = subplan_signatures(serve.PlanTemplate(
        serve.templates._q6_template, name="q6twin").query.plan)
    roots_a = {h for h, _ in subs.values()}
    roots_b = {h for h, _ in twin.values()}
    assert roots_a == roots_b


# ---------------------------------------------------------------------------
# compiled-plan cache: one trace per template, FIFO, invalidation
# ---------------------------------------------------------------------------

def test_one_trace_per_template_across_bindings(db):
    srv = serve.QueryServer(db)
    reqs = _requests((1, 6))         # 3 + 3 samples, interleaved
    srv.serve(reqs, infer=True)
    assert srv.recompiles == 2, "re-binding must never re-trace"
    assert srv.cache_hits == len(reqs) - 2
    # a jitted and an eager execution of the same binding agree
    got = srv.submit(6, {"q6_qty": 25}, infer=True)
    ref, _ = B.run_local(
        serve.TEMPLATES[6].bind(q6_qty=25).with_inference(True),
        db, jit=False)
    np.testing.assert_allclose(got["revenue"], ref["revenue"], rtol=1e-9)


def test_plancache_fifo_bound(db):
    cache = serve.PlanCache(max_entries=2)
    cache.put(db, "a", 1)
    cache.put(db, "b", 2)
    cache.put(db, "c", 3)            # evicts "a" (FIFO)
    assert cache.get(db, "a") is None
    assert cache.get(db, "b") == 2 and cache.get(db, "c") == 3
    assert len(cache) == 2 and cache.evictions == 1


def test_stats_override_evicts_compiled_templates(db):
    srv = serve.QueryServer(db)
    srv.submit(6, infer=True)
    assert srv.recompiles == 1 and len(srv.cache) == 1
    with planner.stats_override(db, {}):
        # entry invalidated: serving inside the scope must recompile against
        # the overridden statistics
        assert len(srv.cache) == 0
        srv.submit(6, infer=True)
        assert srv.recompiles == 2
    # exit invalidated too: the scope's program must not serve real traffic
    assert len(srv.cache) == 0
    srv.submit(6, infer=True)
    assert srv.recompiles == 3


def test_table_mutation_evicts_compiled_templates():
    db2 = tpch.generate(0.002, seed=3)
    srv = serve.QueryServer(db2)
    before = srv.submit(6, infer=True)
    assert srv.recompiles == 1
    # the documented mutation protocol: change tables, then invalidate_stats
    li = db2.tables["lineitem"]
    li["l_quantity"] = np.minimum(np.asarray(li["l_quantity"]), 10)
    planner.invalidate_stats(db2)
    assert len(srv.cache) == 0, "stale template would serve wrong answers"
    srv2 = serve.QueryServer(db2)   # tables snapshot taken at server build
    after = srv2.submit(6, infer=True)
    assert srv2.recompiles == 1
    assert not np.array_equal(before["revenue"], after["revenue"])


def test_invalidation_scoped_to_the_database(db):
    db2 = tpch.generate(0.002, seed=3)
    srv = serve.QueryServer(db)
    srv.submit(6, infer=True)
    planner.invalidate_stats(db2)    # a DIFFERENT database
    assert len(srv.cache) == 1, "foreign invalidation must not evict"


# ---------------------------------------------------------------------------
# batch executor: differential vs sequential + sharing + overflow isolation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("infer,wire", [(True, "narrow"), (True, "wide"),
                                        (False, "narrow")])
def test_batch_differential_fast(db, infer, wire):
    """Mixed interleaved parameterized stream through the batch executor ==
    sequential one-query-at-a-time eager execution, byte-identical, on both
    planner legs and both wire legs."""
    reqs = _requests(FAST_QIDS)
    bx = serve.BatchExecutor(db, wire_format=wire)
    got = bx.run_batch(reqs, infer=infer)
    assert bx.shared_hits > 0, "no cross-query sharing happened"
    for (t, s), out in zip(reqs, got):
        ref, _ = B.run_local(t.bind(**s).with_inference(infer), db,
                             jit=False, wire_format=wire)
        assert set(ref) == set(out), t.name
        for k in ref:
            assert np.array_equal(ref[k], out[k]), (t.name, k, infer, wire)


@pytest.mark.slow
@pytest.mark.parametrize("infer", [True, False])
def test_batch_differential_all22(db, infer):
    reqs = _requests(range(1, 23))
    bx = serve.BatchExecutor(db)
    got = bx.run_batch(reqs, infer=infer)
    for (t, s), out in zip(reqs, got):
        ref, _ = B.run_local(t.bind(**s).with_inference(infer), db,
                             jit=False)
        for k in ref:
            assert np.array_equal(ref[k], out[k]), (t.name, k)


def _lying_template():
    """groups_hint=2 undercounts orders wildly: the hash-compaction
    dictionary overflows at any sane capacity factor."""
    g = scan("orders").group_by(["o_custkey", "o_orderkey"],
                                [("n", "count", None)],
                                exchange="gather", final=True, groups_hint=2)
    return g.finalize(replicated=True)


def test_server_overflow_recovers_conservatively(db):
    lying = serve.PlanTemplate(_lying_template, name="lying")
    srv = serve.QueryServer(db)
    out = srv.submit(lying, infer=True)
    assert srv.overflow_reruns == 1
    # one row per order, correct despite the lying claim
    assert out["n"].size == np.asarray(
        db.tables["orders"]["o_orderkey"]).size
    assert (out["n"] >= 1).all()


def test_batch_overflow_isolated(db):
    """A lying request re-runs conservatively; its neighbours (before AND
    after it in the batch) stay byte-identical to sequential execution."""
    lying = serve.PlanTemplate(_lying_template, name="lying")
    t6, t1 = serve.TEMPLATES[6], serve.TEMPLATES[1]
    reqs = [(t6, {}), (lying, {}), (t1, {"q1_cutoff": days("1998-08-15")})]
    bx = serve.BatchExecutor(db)
    got = bx.run_batch(reqs, infer=True)
    assert bx.overflow_reruns == 1
    assert got[1]["n"].size == np.asarray(
        db.tables["orders"]["o_orderkey"]).size
    for (t, s), out in ((reqs[0], got[0]), (reqs[2], got[2])):
        ref, _ = B.run_local(t.bind(**s).with_inference(True), db, jit=False)
        for k in ref:
            assert np.array_equal(ref[k], out[k]), (t.name, k)


# ---------------------------------------------------------------------------
# fault runner integration
# ---------------------------------------------------------------------------

def test_query_runner_accepts_template_bindings(db, tmp_path):
    from repro.distributed.fault import QueryRunner
    runner = QueryRunner(db, None,
                         lineage=LineageStore(str(tmp_path / "ln")))
    runner.chaos = None              # pin: no env-leg injection here
    rr = runner.run(serve.TEMPLATES[6],
                    bindings={"q6_disc_lo": 0.03, "q6_disc_hi": 0.05})
    ref, _ = B.run_local(
        serve.TEMPLATES[6].bind(q6_disc_lo=0.03, q6_disc_hi=0.05),
        db, jit=False)
    np.testing.assert_allclose(rr.result["revenue"], ref["revenue"],
                               rtol=1e-9)
    with pytest.raises(TypeError, match="plan template"):
        runner.run(QUERIES[6], bindings={"q6_qty": 24})


def test_default_bindings_match_literal_queries(db):
    """samples[0] (all defaults) reproduces the literal query byte-for-byte
    for every parameterized template."""
    for qid in (1, 3, 5, 6):
        t = serve.TEMPLATES[qid]
        ref, _ = B.run_local(QUERIES[qid].with_inference(False), db,
                             jit=False)
        got, _ = B.run_local(t.bind().with_inference(False), db, jit=False)
        for k in ref:
            assert np.array_equal(ref[k], got[k]), (qid, k)

"""Sharding-rule unit tests (pure spec logic — no devices needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.shardings import MeshAxes, cache_specs, param_specs
from repro.models import Model


@pytest.fixture(scope="module")
def qwen_structs():
    cfg = get_config("qwen1_5_110b")
    model = Model(cfg, expert_pad=16, vocab_pad=128)
    p = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0),
                                          dtype=jnp.bfloat16))
    c = jax.eval_shape(lambda: model.init_cache(128, 1024,
                                                dtype=jnp.bfloat16))
    return cfg, model, p, c


def test_param_specs_2d_sharding(qwen_structs):
    cfg, model, p, _ = qwen_structs
    specs = param_specs(p, MeshAxes(fsdp=("data",), tp="model"))
    assert specs["embed"] == P("model", "data")
    assert specs["lm_head"] == P("data", "model")
    # stacked layers get a leading None
    seg = specs["segments"][0]
    assert seg["attn"]["wq"] == P(None, "data", "model")
    assert seg["attn"]["wo"] == P(None, "model", "data")
    assert seg["ln1"] == P(None, None)          # norms replicate
    # every spec rank matches its leaf rank
    def chk(leaf, spec):
        assert len(spec) <= leaf.ndim
    jax.tree.map(chk, p, specs, is_leaf=lambda x: isinstance(x, P))


def test_param_specs_serving_tp_only(qwen_structs):
    """Empty fsdp -> weight-stationary serving sharding (It-8)."""
    _, _, p, _ = qwen_structs
    specs = param_specs(p, MeshAxes(fsdp=(), tp="model"))
    assert specs["embed"] == P("model", None)
    assert specs["segments"][0]["attn"]["wq"] == P(None, None, "model")


def test_param_specs_multipod_fsdp(qwen_structs):
    _, _, p, _ = qwen_structs
    specs = param_specs(p, MeshAxes(fsdp=("pod", "data"), tp="model"))
    assert specs["embed"] == P("model", ("pod", "data"))


def test_moe_expert_specs():
    cfg = get_config("deepseek_v2_236b")
    model = Model(cfg, expert_pad=16, vocab_pad=128)
    p = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0),
                                          dtype=jnp.bfloat16))
    specs = param_specs(p, MeshAxes(fsdp=("data",), tp="model"))
    moe = specs["segments"][1]["moe"]
    assert moe["w_gate"] == P(None, "model", "data", None)   # experts -> EP
    assert moe["w_down"] == P(None, "model", None, "data")


def test_cache_specs_batch_vs_seq_sharding(qwen_structs):
    cfg, model, _, c = qwen_structs
    mesh_shape = {"data": 16, "model": 16}
    axes = MeshAxes(fsdp=("data",), tp="model")
    # batch 128 over 16 -> batch-sharded; kv=8 not divisible by 16 ->
    # heads replicated
    specs = cache_specs(cfg, c, axes, 128, mesh_shape)
    k_spec = specs["segments"][0]["k"]
    assert k_spec == P(None, "data", None, None, None)
    # batch 1 -> sequence-sharded flash-decode
    c1 = jax.eval_shape(lambda: model.init_cache(1, 1024,
                                                 dtype=jnp.bfloat16))
    specs1 = cache_specs(cfg, c1, axes, 1, mesh_shape)
    assert specs1["segments"][0]["k"] == P(None, None, "data", None, None)
    # tp=4 divides kv=8 -> heads shard too
    specs4 = cache_specs(cfg, c, axes, 128, {"data": 64, "model": 4})
    assert specs4["segments"][0]["k"] == P(None, "data", None, "model", None)

"""Sort-tax regression tests: deferred compaction + join-path equivalence.

Covers the three tentpole invariants:
  * masked (uncompacted) tables produce identical results to eagerly
    compacted ones across filter/join/group-by chains;
  * the Pallas hash-probe join path is byte-identical to the searchsorted
    path on all 22 TPC-H queries (with the NumPy RefContext as oracle);
  * the HLO ``sort`` op count of representative local plans stays within the
    post-optimization budget (the CI gate runs the fuller check in
    ``benchmarks/bench_sort_tax.py``).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import backend as B
from repro.core import relational as R
from repro.core.table import Table, from_numpy, to_numpy
from repro.data import tpch
from repro.distributed.hlo_analysis import op_histogram
from repro.queries import QUERIES


@pytest.fixture(scope="module")
def db():
    return tpch.generate(0.005, seed=11)


def _rows(t):
    """Canonical row multiset of a table: sorted tuples over all columns."""
    d = to_numpy(t)
    names = sorted(d)
    rows = sorted(zip(*[d[n].tolist() for n in names]))
    return names, rows


def _random_table(seed, n=211, cap=256):
    rng = np.random.default_rng(seed)
    return from_numpy({
        "k": rng.integers(0, 15, n).astype(np.int64),
        "k2": rng.integers(0, 6, n).astype(np.int64),
        "v": rng.normal(size=n),
    }, capacity=cap)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_masked_equals_compacted_filter_join_group_chain(seed):
    """Lazy-mask pipeline == the same pipeline with eager compaction after
    every operator (the seed engine's invariant)."""
    t = _random_table(seed)
    rng = np.random.default_rng(100 + seed)
    bn = 10
    build = from_numpy({"bk": np.arange(bn, dtype=np.int64),
                        "bv": rng.normal(size=bn)}, capacity=16)
    build = R.filter_rows(build, build["bk"] != 3)  # masked build side too

    def chain(t, build, eager):
        step = (lambda x: R.ensure_compact(x)) if eager else (lambda x: x)
        t = step(R.filter_rows(t, t["k"] < 12))
        t = step(R.join_unique(t, build, t["k"], build["bk"], ["bv"]))
        t = step(R.semi_join(t, build, t["k2"], build["bk"]))
        t = step(R.anti_join(t, build, t["k"] * 0 + 7, build["bk"])) \
            if seed % 2 else t
        g = R.group_aggregate(t, ["k", "k2"], [
            ("s", "sum", "v"), ("c", "count", None),
            ("mn", "min", "bv"), ("mx", "max", "v")])
        return R.sort_by(g, [("k", True), ("k2", False)])

    lazy = chain(t, build, eager=False)
    eager = chain(t, build, eager=True)
    nl, rl = _rows(lazy)
    ne, re_ = _rows(eager)
    assert nl == ne
    assert int(lazy.count) == int(eager.count)
    np.testing.assert_allclose(np.asarray(rl, dtype=np.float64),
                               np.asarray(re_, dtype=np.float64), rtol=1e-12)


def test_masked_count_invariant():
    """count == valid.sum() is preserved by every mask-producing op."""
    t = _random_table(7)
    f = R.filter_rows(t, t["v"] > 0)
    assert f.valid is not None
    assert int(f.count) == int(np.asarray(f.valid).sum())
    build = from_numpy({"bk": np.arange(5, dtype=np.int64)}, capacity=8)
    s = R.semi_join(f, build, f["k"], build["bk"])
    assert int(s.count) == int(np.asarray(s.valid).sum())
    c = R.ensure_compact(s)
    assert c.valid is None
    assert int(c.count) == int(s.count)


def test_sort_by_single_key_matches_multipass(db):
    """One multi-operand lax.sort == the seed's per-key passes (via numpy)."""
    t = _random_table(11)
    got = to_numpy(R.sort_by(t, [("k", True), ("v", False), ("k2", True)]))
    d = to_numpy(t)
    order = np.lexsort((d["k2"], -d["v"], d["k"]))
    for c in ("k", "k2", "v"):
        np.testing.assert_array_equal(got[c], d[c][order])


def test_combine_keys_bits_packing():
    a = jnp.asarray([1, 2, 3], dtype=jnp.int64)
    b = jnp.asarray([4, 5, 6], dtype=jnp.int64)
    c = jnp.asarray([7, 0, 1], dtype=jnp.int64)
    k = R.combine_keys([a, b, c], bits=[8, 8, 8])
    np.testing.assert_array_equal(
        np.asarray(k), ((np.array([1, 2, 3]) << 8 | [4, 5, 6]) << 8) | [7, 0, 1])
    with pytest.raises(ValueError):
        R.combine_keys([a, b, c], bits=[32, 31, 8])
    with pytest.raises(ValueError):
        R.combine_keys([a, b, c])


@pytest.mark.parametrize("qid", sorted(QUERIES))
def test_hash_join_path_byte_identical(db, qid):
    """Kernel-backed hash-probe joins == searchsorted joins, bit for bit,
    and both match the NumPy reference oracle."""
    r_sorted, _ = B.run_local(QUERIES[qid], db, join_method="sorted")
    r_hash, _ = B.run_local(QUERIES[qid], db, join_method="hash")
    assert set(r_sorted) == set(r_hash)
    for k in r_sorted:
        np.testing.assert_array_equal(r_sorted[k], r_hash[k],
                                      err_msg=f"q{qid} {k}")
    r_ref, _ = B.run_reference(QUERIES[qid], db)
    for k in set(r_ref) & set(r_hash):
        np.testing.assert_allclose(np.asarray(r_hash[k], np.float64),
                                   np.asarray(r_ref[k], np.float64),
                                   rtol=1e-7, err_msg=f"q{qid} {k} vs oracle")


# Absolute per-query HLO sort budgets for the local plans (phase 2/3:
# planner-inferred group-bys are sortless, shuffle dispatch is sortless).
# Tighter than the seed-relative 40% rule; the fuller gate lives in
# benchmarks/bench_sort_tax.py.  Compiled with inference pinned ON so the
# REPRO_PLANNER=0 CI leg measures the same program.
#   q1  = 1 final ORDER BY              (group-by direct, was 2)
#   q3  = 4 (3 once the planner proves l_orderkey's width at this SF)
#   q6  = 0 (scalar aggregation is the trivial direct domain)
#   q9  = 4 build indexes + 1 final ORDER BY (group-by direct, was 6)
#   q12 = 1 build index + 1 final ORDER BY   (group-by direct, was 3)
#   q13 = 1 build index + 1 final ORDER BY   (c_count group-by rides the
#         hash-compaction dictionary — data-dependent domain, zero sorts —
#         and the o_custkey group-by is direct; was 3)
_MAX_SORTS = {1: 1, 3: 4, 6: 0, 9: 5, 12: 2, 13: 2}


@pytest.mark.parametrize("qid", sorted(_MAX_SORTS))
def test_hlo_sort_count_budget(db, qid):
    tables = B._np_db_to_tables(db)

    def run(tables):
        ctx = B.LocalContext(db, tables)
        out = QUERIES[qid].run(ctx, infer=True)
        if isinstance(out, dict):
            out = Table({k: jnp.asarray(v).reshape(1) for k, v in out.items()},
                        jnp.asarray(1, jnp.int32))
        return R.ensure_compact(out), ctx.overflow

    hlo = jax.jit(run).lower(tables).compile().as_text()
    nsort = op_histogram(hlo, ops=("sort",))["sort"]
    assert nsort <= _MAX_SORTS[qid], \
        f"q{qid}: {nsort} HLO sorts > budget {_MAX_SORTS[qid]}"


def test_group_aggregate_with_key_bits_zero_sorts():
    """The direct-addressing path must lower to ZERO HLO sorts."""
    t = _random_table(13)

    def run(t):
        return R.group_aggregate(t, ["k", "k2"], [
            ("s", "sum", "v"), ("c", "count", None),
            ("mn", "min", "v"), ("mx", "max", "v")], key_bits=[4, 3])

    hlo = jax.jit(run).lower(t).compile().as_text()
    assert op_histogram(hlo, ops=("sort",))["sort"] == 0


@pytest.mark.parametrize("use_kernel", [True, False])
def test_group_aggregate_hash_path_zero_sorts(use_kernel):
    """The hash-compaction path (groups_hint, NO key_bits) must lower to ZERO
    HLO sorts on BOTH aggregation engines — dictionary build, ascending-key
    rank derivation, and the segsum reduce are all sort-free."""
    rng = np.random.default_rng(15)
    t = from_numpy({"k": rng.integers(0, 1 << 40, 211).astype(np.int64),
                    "v": rng.normal(size=211)}, capacity=256)

    def run(t):
        return R.group_aggregate(t, ["k"], [
            ("s", "sum", "v"), ("c", "count", None),
            ("mn", "min", "v"), ("mx", "max", "v")],
            method="hash", groups_hint=256, use_kernel=use_kernel,
            return_overflow=True)

    hlo = jax.jit(run).lower(t).compile().as_text()
    assert op_histogram(hlo, ops=("sort",))["sort"] == 0


def test_scalar_aggregate_zero_sorts():
    t = _random_table(14)

    def run(t):
        return R.group_aggregate(t, [], [("s", "sum", "v"),
                                         ("c", "count", None)])

    hlo = jax.jit(run).lower(t).compile().as_text()
    assert op_histogram(hlo, ops=("sort",))["sort"] == 0


def test_shuffle_dispatch_zero_sorts():
    """Counting-rank destination dispatch must lower to ZERO HLO sorts."""
    from repro.core import exchange as EX
    dest = jnp.asarray(np.random.default_rng(0).integers(0, 9, 512),
                       jnp.int32)

    def run(d):
        return EX._dispatch_offsets(d, 8)

    hlo = jax.jit(run).lower(dest).compile().as_text()
    assert op_histogram(hlo, ops=("sort",))["sort"] == 0

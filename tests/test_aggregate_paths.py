"""Differential tests: sortless (direct-addressing) vs sorted group_aggregate.

The phase-2 sort-tax work routes small-domain group-bys through dense group
ids + the ``kernels/segsum`` one-hot MXU reduce instead of an argsort, and
ranks shuffle rows with a radix-histogram counting rank instead of a stable
sort.  These tests pin the two paths together: same groups, same order, same
values (exact for int/count/min/max, 1e-12 for float sums), across masked and
compacted inputs, empty/all-invalid tables, single groups, and wrong hints
(which must flag overflow, never silently drop groups).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import backend as B
from repro.core import exchange as EX
from repro.core import relational as R
from repro.core.table import from_numpy, to_numpy
from repro.data import tpch
from repro.kernels.segsum import ops as ss

OPS4 = [("s", "sum", "v"), ("c", "count", None),
        ("mn", "min", "v"), ("mx", "max", "v")]


def _random_table(seed, n=211, cap=256, kmax=16, k2max=8):
    rng = np.random.default_rng(seed)
    return from_numpy({
        "k": rng.integers(0, kmax, n).astype(np.int64),
        "k2": rng.integers(0, k2max, n).astype(np.int64),
        "v": rng.normal(size=n),
        "i": rng.integers(-50, 50, n).astype(np.int64),
    }, capacity=cap)


def _assert_tables_equal(got, want, float_cols=("s",)):
    gd, wd = to_numpy(got), to_numpy(want)
    assert set(gd) == set(wd)
    assert int(got.count) == int(want.count)
    for k in wd:
        if k in float_cols or wd[k].dtype.kind == "f":
            np.testing.assert_allclose(gd[k], wd[k], rtol=1e-12, atol=1e-12,
                                       err_msg=k)
        else:
            np.testing.assert_array_equal(gd[k], wd[k], err_msg=k)


@pytest.mark.parametrize("use_kernel", [True, False])
@pytest.mark.parametrize("masked", [True, False])
def test_direct_matches_sorted_all_ops(use_kernel, masked):
    t = _random_table(0)
    if masked:
        t = R.filter_rows(t, t["v"] > -0.4)   # leaves a validity mask
    aggs = OPS4 + [("imn", "min", "i"), ("imx", "max", "i"),
                   ("isum", "sum", "i")]
    direct = R.group_aggregate(t, ["k", "k2"], aggs, key_bits=[4, 3],
                               method="direct", use_kernel=use_kernel)
    sortd = R.group_aggregate(t, ["k", "k2"], aggs, method="sort")
    _assert_tables_equal(direct, sortd)


@pytest.mark.parametrize("use_kernel", [True, False])
def test_direct_empty_and_all_invalid(use_kernel):
    t = _random_table(1, n=0, cap=32)
    for tt in (t, R.filter_rows(_random_table(2), _random_table(2)["v"] > 99)):
        direct = R.group_aggregate(tt, ["k"], OPS4, key_bits=[4],
                                   method="direct", use_kernel=use_kernel)
        sortd = R.group_aggregate(tt, ["k"], OPS4, method="sort")
        assert int(direct.count) == int(sortd.count) == 0
        _assert_tables_equal(direct, sortd)


def test_direct_single_group():
    t = _random_table(3)
    t = t.replace(k=jnp.zeros_like(t["k"]) + 5)
    direct = R.group_aggregate(t, ["k"], OPS4, key_bits=[4], method="direct")
    sortd = R.group_aggregate(t, ["k"], OPS4, method="sort")
    assert int(direct.count) == 1
    _assert_tables_equal(direct, sortd)


def test_scalar_agg_direct_matches_sorted():
    t = R.filter_rows(_random_table(4), _random_table(4)["v"] < 0.9)
    direct = R.group_aggregate(t, [], OPS4, method="direct")
    sortd = R.group_aggregate(t, [], OPS4, method="sort")
    _assert_tables_equal(direct, sortd)


def test_lying_key_bits_flags_overflow_never_corrupts():
    """key_bits smaller than the true domain: out-of-domain groups go to the
    dead slot and the overflow flag fires — in-domain groups stay exact."""
    t = _random_table(5, kmax=16)
    direct, ov = R.group_aggregate(t, ["k"], OPS4, key_bits=[3],
                                   method="direct", return_overflow=True)
    assert bool(ov)
    # the in-domain groups (k < 8) must still match the sorted path exactly
    t8 = R.filter_rows(t, t["k"] < 8)
    sortd = R.group_aggregate(t8, ["k"], OPS4, method="sort")
    _assert_tables_equal(direct, sortd)
    # honest bits: no overflow
    _, ov2 = R.group_aggregate(t, ["k"], OPS4, key_bits=[4],
                               method="direct", return_overflow=True)
    assert not bool(ov2)


def test_lying_bits_on_non_leading_column_flags_overflow():
    """An oversized value in a NON-leading key column ORs into its neighbor's
    bits and aliases an in-range packed key — the per-column domain check
    must still flag it (regression: a packed-key range check alone misses
    this corruption)."""
    n = 32
    cols = {"k": np.full(n, 1, np.int64), "k2": np.full(n, 5, np.int64),
            "v": np.ones(n)}
    t = from_numpy(cols, capacity=n)
    # claim k2 < 4 (false: k2 == 5); packed key (1<<2)|5 = 9 < 2^4 aliases
    # the honest group (k=2, k2=1)
    direct, ov = R.group_aggregate(t, ["k", "k2"], [("s", "sum", "v")],
                                   key_bits=[2, 2], method="direct",
                                   return_overflow=True)
    assert bool(ov)
    assert int(direct.count) == 0      # every row is out of claimed domain


def test_key_bits_larger_than_true_groups():
    """A generous domain claim shrinks correctly — no phantom groups."""
    t = _random_table(6, kmax=5)
    direct = R.group_aggregate(t, ["k"], OPS4, key_bits=[10], method="direct")
    sortd = R.group_aggregate(t, ["k"], OPS4, method="sort")
    _assert_tables_equal(direct, sortd)


def test_auto_dispatch_and_forced_direct_raises():
    t = _random_table(7)
    # auto: bits present and small -> direct == sort
    auto = R.group_aggregate(t, ["k"], OPS4, key_bits=[4])
    sortd = R.group_aggregate(t, ["k"], OPS4, method="sort")
    _assert_tables_equal(auto, sortd)
    with pytest.raises(ValueError):
        R.group_aggregate(t, ["k"], OPS4, method="direct")  # no bits
    with pytest.raises(ValueError):
        R.group_aggregate(t, ["k"], OPS4, key_bits=[20], method="direct")


def test_groups_hint_smaller_and_larger_than_true_groups():
    """Backend-level: hint < true groups flags ctx.overflow (re-execution),
    hint >= true groups returns the exact result — never a silent drop."""
    db = tpch.generate(0.002, seed=3)
    tables = B._np_db_to_tables(db)
    o = tables["orders"]

    def run(hint):
        ctx = B.LocalContext(db, tables)
        g = ctx.group_by(o, ["o_orderpriority"],
                         [("n", "count", None)], groups_hint=hint,
                         key_bits=[ctx.dict_bits("o_orderpriority")])
        return g, bool(ctx.overflow)

    big, ov_big = run(8)
    assert not ov_big and int(big.count) == 5
    small, ov_small = run(2)
    assert ov_small          # 5 priorities cannot fit 2 slots -> re-execute
    assert int(np.asarray(small.count)) == 2  # shrunk, flagged, not silent


# ---------------------------------------------------------------------------
# hash-compaction path (data-dependent domains): hash == sort, byte for byte
# ---------------------------------------------------------------------------

def _wide_key_table(seed, n=211, cap=256, masked=False):
    """Keys from a WIDE, data-dependent domain (negatives included) — exactly
    what the direct path cannot take and the hash dictionary exists for."""
    rng = np.random.default_rng(seed)
    t = from_numpy({
        "k": rng.integers(-1000, 1 << 40, n).astype(np.int64),
        "v": rng.normal(size=n),
        "i": rng.integers(-50, 50, n).astype(np.int64),
    }, capacity=cap)
    if masked:
        t = R.filter_rows(t, t["v"] > -0.4)
    return t


@pytest.mark.parametrize("use_kernel", [True, False])
@pytest.mark.parametrize("masked", [True, False])
def test_hash_matches_sorted_all_ops(use_kernel, masked):
    t = _wide_key_table(20, masked=masked)
    aggs = OPS4 + [("imn", "min", "i"), ("imx", "max", "i"),
                   ("isum", "sum", "i")]
    hashed = R.group_aggregate(t, ["k"], aggs, method="hash",
                               groups_hint=256, use_kernel=use_kernel)
    sortd = R.group_aggregate(t, ["k"], aggs, method="sort")
    _assert_tables_equal(hashed, sortd)


@pytest.mark.parametrize("use_kernel", [True, False])
def test_hash_two_col_keys_match_sorted(use_kernel):
    rng = np.random.default_rng(21)
    n = 180
    t = from_numpy({
        "a": rng.integers(0, 1 << 20, n).astype(np.int64),
        "b": rng.integers(0, 7, n).astype(np.int64),
        "v": rng.normal(size=n),
    }, capacity=256)
    hashed = R.group_aggregate(t, ["a", "b"], OPS4, method="hash",
                               groups_hint=512, use_kernel=use_kernel)
    sortd = R.group_aggregate(t, ["a", "b"], OPS4, method="sort")
    _assert_tables_equal(hashed, sortd)


@pytest.mark.parametrize("use_kernel", [True, False])
def test_hash_empty_and_all_invalid(use_kernel):
    t = _random_table(22, n=0, cap=32)
    allinv = R.filter_rows(_wide_key_table(23), _wide_key_table(23)["v"] > 99)
    for tt in (t, allinv):
        hashed = R.group_aggregate(tt, ["k"], OPS4, method="hash",
                                   groups_hint=64, use_kernel=use_kernel)
        sortd = R.group_aggregate(tt, ["k"], OPS4, method="sort")
        assert int(hashed.count) == int(sortd.count) == 0


def test_hash_undercounting_hint_flags_overflow():
    """groups_hint below the true distinct count must flag overflow; the
    headroom factor may still have placed every group, in which case the
    output is complete AND flagged (re-execution discipline, never silent)."""
    t = _wide_key_table(24)                      # ~200 distinct keys
    hashed, ov = R.group_aggregate(t, ["k"], OPS4, method="hash",
                                   groups_hint=32, hash_factor=16.0,
                                   return_overflow=True)
    assert bool(ov)
    sortd = R.group_aggregate(t, ["k"], OPS4, method="sort")
    _assert_tables_equal(hashed, sortd)          # dict held them all anyway
    # honest hint: no overflow
    _, ov2 = R.group_aggregate(t, ["k"], OPS4, method="hash",
                               groups_hint=256, return_overflow=True)
    assert not bool(ov2)


@pytest.mark.parametrize("use_kernel", [True, False])
def test_hash_dict_overflow_escalation_clears(use_kernel):
    """A starved capacity factor leaves rows unplaceable (dictionary smaller
    than the distinct groups) -> overflow; doubling the factor — exactly what
    the fault runner's escalation does — clears it and reproduces the sort
    path.  Unplaced rows are EXCLUDED, never misassigned: every group the
    flagged run does emit is exact."""
    rng = np.random.default_rng(25)
    n = 230
    t = from_numpy({"k": rng.integers(0, 1 << 35, n).astype(np.int64),
                    "v": rng.normal(size=n)}, capacity=256)
    aggs = [("s", "sum", "v"), ("c", "count", None)]
    factor = 0.125                               # dict cap 32 < ~200 distinct
    hashed, ov = R.group_aggregate(t, ["k"], aggs, method="hash",
                                   groups_hint=230, hash_factor=factor,
                                   use_kernel=use_kernel,
                                   return_overflow=True)
    assert bool(ov)
    sortd = to_numpy(R.group_aggregate(t, ["k"], aggs, method="sort"))
    got = to_numpy(hashed)
    want = {int(k): (s, c) for k, s, c in
            zip(sortd["k"], sortd["s"], sortd["c"])}
    for k, s, c in zip(got["k"], got["s"], got["c"]):
        ws, wc = want[int(k)]
        assert wc == c and abs(ws - s) < 1e-12   # emitted groups are exact
    while bool(ov):                              # QueryRunner's discipline
        factor *= 2.0
        hashed, ov = R.group_aggregate(t, ["k"], aggs, method="hash",
                                       groups_hint=230, hash_factor=factor,
                                       use_kernel=use_kernel,
                                       return_overflow=True)
        assert factor <= 16.0, "escalation failed to clear dict overflow"
    _assert_tables_equal(hashed,
                         R.group_aggregate(t, ["k"], aggs, method="sort"))


def test_hash_auto_dispatch_and_guards():
    t = _wide_key_table(26)
    # auto: no key_bits but a hint -> hash == sort
    auto = R.group_aggregate(t, ["k"], OPS4, groups_hint=256)
    sortd = R.group_aggregate(t, ["k"], OPS4, method="sort")
    _assert_tables_equal(auto, sortd)
    with pytest.raises(ValueError):
        R.group_aggregate(t, ["k"], OPS4, method="hash")      # no hint
    with pytest.raises(ValueError):
        R.group_aggregate(t, ["k"], OPS4, method="hash",
                          groups_hint=R.HASH_AGG_GROUPS_MAX + 1)
    # direct outranks hash when both are eligible (cheaper: no dictionary)
    t2 = _random_table(27)
    both = R.group_aggregate(t2, ["k"], OPS4, key_bits=[4], groups_hint=16)
    _assert_tables_equal(both, R.group_aggregate(t2, ["k"], OPS4,
                                                 method="sort"))


@pytest.mark.parametrize("use_kernel", [True, False])
def test_q13_hash_path_matches_sort_path_both_planner_legs(use_kernel):
    """The tentpole acceptance case: Q13's data-dependent c_count histogram.
    Inference ON compiles the hash path (planner rule), inference OFF the
    single-sort path — byte-identical results per engine, and both match the
    NumPy reference."""
    from repro.queries import QUERIES
    db = tpch.generate(0.002, seed=3)
    r_hash, _ = B.run_local(QUERIES[13].with_inference(True), db,
                            use_kernel=use_kernel)
    r_sort, _ = B.run_local(QUERIES[13].with_inference(False), db,
                            use_kernel=use_kernel)
    assert set(r_hash) == set(r_sort)
    for k in r_hash:
        np.testing.assert_array_equal(r_hash[k], r_sort[k], err_msg=k)
    r_ref, _ = B.run_reference(QUERIES[13], db)
    for k in set(r_ref) & set(r_hash):
        np.testing.assert_allclose(np.asarray(r_hash[k], np.float64),
                                   np.asarray(r_ref[k], np.float64),
                                   rtol=1e-7, err_msg=k)


# ---------------------------------------------------------------------------
# shuffle dispatch: counting rank == stable-sort rank, byte for byte
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [True, False])
@pytest.mark.parametrize("seed,n,parts", [(0, 1000, 8), (1, 77, 3),
                                          (2, 4096, 16), (3, 8, 1)])
def test_dispatch_offsets_match_stable_sort(seed, n, parts, use_kernel):
    rng = np.random.default_rng(seed)
    # include the drop bucket `parts` (padding rows), as shuffle produces
    dest = rng.integers(0, parts + 1, n).astype(np.int32)
    slot, counts = EX._dispatch_offsets(jnp.asarray(dest), parts,
                                        use_kernel=use_kernel)
    # oracle: stable sort on destination, position within the group
    order = np.argsort(dest, kind="stable")
    want = np.empty(n, np.int64)
    start = {}
    for i in order:
        want[i] = start.get(dest[i], 0)
        start[dest[i]] = want[i] + 1
    np.testing.assert_array_equal(np.asarray(slot), want)
    np.testing.assert_array_equal(np.asarray(counts),
                                  np.bincount(dest, minlength=parts + 1)[:parts])


# ---------------------------------------------------------------------------
# segsum dead-slot routing at lane boundaries (regression)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("groups", [127, 128, 129])
def test_segsum_dead_slot_lane_boundary(groups):
    """The dead slot is ALWAYS index ``groups``: caller sentinels (gid ==
    groups) and out-of-range ids must never alias a real group, even when
    groups+1 sits exactly on a 128-lane tile boundary (groups = 127)."""
    rng = np.random.default_rng(groups)
    n = 500
    gids = rng.integers(0, groups + 1, n).astype(np.int32)   # incl. sentinel
    gids[:4] = [groups, groups - 1, -3, groups + 7]          # edge ids
    vals = rng.normal(size=n).astype(np.float32)
    want = np.zeros(groups, np.float64)
    for g, v in zip(gids, vals):
        if 0 <= g < groups:
            want[g] += v
    for use_kernel in (True, False):
        got = ss.segment_reduce(jnp.asarray(gids), jnp.asarray(vals), groups,
                                op="sum", use_kernel=use_kernel)
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-4)
    # count / min / max honor the same routing
    cnt = ss.segment_reduce(jnp.asarray(gids), None, groups, op="count")
    np.testing.assert_array_equal(
        np.asarray(cnt), np.bincount(gids[(gids >= 0) & (gids < groups)],
                                     minlength=groups))
    mn = ss.segment_reduce(jnp.asarray(gids), jnp.asarray(vals), groups,
                           op="min")
    mask = (gids >= 0) & (gids < groups)
    for g in range(groups):
        rows = vals[mask & (gids == g)]
        if len(rows):
            assert np.isclose(np.asarray(mn)[g], rows.min())


@pytest.mark.parametrize("op", ["min", "max"])
def test_segment_minmax_kernel_matches_ref(op):
    rng = np.random.default_rng(11)
    gids = jnp.asarray(rng.integers(0, 130, 1000).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=1000).astype(np.float32))
    got = ss.segment_reduce(gids, vals, 130, op=op, use_kernel=True)
    want = ss.segment_reduce(gids, vals, 130, op=op, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# query-level: sortless engine == jnp-oracle engine == NumPy reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qid", [1, 4, 6, 12])
def test_hinted_queries_kernel_vs_oracle_paths(qid):
    """The hinted (sortless) plans must be byte-identical between the Pallas
    kernel path and the jnp scatter-reduce path, and match the reference."""
    from repro.queries import QUERIES
    db = tpch.generate(0.002, seed=5)
    r_k, _ = B.run_local(QUERIES[qid], db, use_kernel=True)
    r_j, _ = B.run_local(QUERIES[qid], db, use_kernel=False)
    assert set(r_k) == set(r_j)
    for k in r_k:
        np.testing.assert_allclose(np.asarray(r_k[k], np.float64),
                                   np.asarray(r_j[k], np.float64),
                                   rtol=1e-9, err_msg=f"q{qid} {k}")
    r_ref, _ = B.run_reference(QUERIES[qid], db)
    for k in set(r_ref) & set(r_k):
        np.testing.assert_allclose(np.asarray(r_k[k], np.float64),
                                   np.asarray(r_ref[k], np.float64),
                                   rtol=1e-7, err_msg=f"q{qid} {k} vs oracle")

"""Chaos harness tests: seeded fault injection, the failure taxonomy, the
policy-driven QueryRunner, wire integrity checksums and lineage recovery.

Layers:

  * **Checksum** — the rotated-XOR fold provably catches every single-bit
    flip (exhaustive over bit positions + a seeded random sweep standing in
    for hypothesis, which the image does not ship); flips in the payload,
    the count word and the checksum word itself all mismatch.
  * **Injection** — the seeded FaultPlan fires the scheduled fault at the
    scheduled cut/visit/attempt and nowhere else; REPRO_CHAOS parsing.
  * **Policy** — classification routes each failure kind down its own
    recovery path: transient -> backoff retry, corrupt -> wide-format
    re-run, overflow -> escalation ladder, deterministic -> raise on
    attempt 1.  The chaos differential sweep proves recovery is
    byte-identical to the fault-free run on both planner legs (subset in
    the fast lane; all 22 queries under the REPRO_CHAOS CI leg).
  * **Lineage** — exchange snapshots resume the plan suffix; config legs
    and CRC damage invalidate snapshots instead of poisoning results.
"""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import backend as B
from repro.core import wire as W
from repro.core.compat import make_mesh
from repro.data import tpch
from repro.distributed import checkpoint as ckpt
from repro.distributed.chaos import (ChaosInjector, FailureKind, FaultPlan,
                                     FaultSpec, TransientFault,
                                     chaos_env_seed)
from repro.distributed.fault import (QueryRunner, RetryPolicy,
                                     classify_failure, skew_imbalance)
from repro.distributed.lineage import LineageStore, run_resumable
from repro.queries import QUERIES


@pytest.fixture(scope="module")
def db():
    return tpch.generate(0.005, seed=11)


@pytest.fixture(scope="module")
def mesh1():
    return make_mesh((1,), ("data",))


# ---------------------------------------------------------------------------
# wire integrity checksum
# ---------------------------------------------------------------------------

def _block(rng, rows=17, words=3):
    return jnp.asarray(rng.integers(-2**31, 2**31, (rows, words),
                                    dtype=np.int64).astype(np.int32))


def _flip(buf, flat_word, bit):
    flat = np.asarray(buf).reshape(-1).copy()
    flat.view(np.uint32)[flat_word] ^= np.uint32(1 << bit)
    return jnp.asarray(flat.reshape(buf.shape))


def test_checksum_single_bit_flip_exhaustive():
    """EVERY single-bit flip of a small payload changes the checksum — the
    position-rotation makes this a certainty, not a probability."""
    rng = np.random.default_rng(0)
    buf = _block(rng, rows=4, words=2)
    base = int(W.payload_checksum(buf))
    for w in range(8):
        for bit in range(32):
            flipped = int(W.payload_checksum(_flip(buf, w, bit)))
            assert flipped != base, (w, bit)
            # exactly one checksum bit differs
            assert bin(flipped ^ base).count("1") == 1, (w, bit)


def test_checksum_random_bit_flips_always_caught():
    """Property sweep (seeded stand-in for hypothesis): random single-bit
    flips in random payloads are ALWAYS caught by block verification, in
    both header modes, whether they land in the payload, the count word or
    the checksum word."""
    rng = np.random.default_rng(7)
    for trial in range(200):
        rows = int(rng.integers(1, 40))
        words = int(rng.integers(1, 6))
        mode = W.header_mode(words, rows)
        payload = _block(rng, rows=rows, words=words)
        count = jnp.asarray(int(rng.integers(0, rows + 1)), jnp.int32)
        csum = W.payload_checksum(payload)
        hdr = jnp.zeros((words,), jnp.int32) \
            .at[0].set(W.encode_header_word0(count, csum, mode))
        if mode == "word":
            hdr = hdr.at[1].set(W.encode_checksum_word(count, csum))
        assert not bool(W.verify_block_checksum(hdr, payload, mode)), trial
        assert int(W.decode_header_word0(hdr[0], mode)) == int(count)

        blk = jnp.concatenate([hdr[None, :], payload])
        w = int(rng.integers(0, blk.size))
        bit = int(rng.integers(0, 32))
        tampered = _flip(blk, w, bit)
        assert bool(W.verify_block_checksum(tampered[0], tampered[1:],
                                            mode)), (trial, w, bit, mode)


def test_checksum_header_word_flips_detected():
    """Flipping the count or the stored checksum itself must mismatch."""
    rng = np.random.default_rng(3)
    payload = _block(rng, rows=8, words=2)
    count = jnp.asarray(5, jnp.int32)
    csum = W.payload_checksum(payload)
    hdr = jnp.zeros((2,), jnp.int32) \
        .at[0].set(W.encode_header_word0(count, csum, "word")) \
        .at[1].set(W.encode_checksum_word(count, csum))
    for w in range(2):
        for bit in (0, 7, 13, 31):
            blk = _flip(jnp.concatenate([hdr[None, :], payload]), w, bit)
            assert bool(W.verify_block_checksum(blk[0], blk[1:], "word"))


def test_header_mode_static_decision():
    assert W.header_mode(2, 10) == "word"
    assert W.header_mode(7, 1 << 20) == "word"    # word 1 is free
    assert W.header_mode(1, 100) == "folded"
    assert W.header_mode(1, (1 << 16) - 1) == "folded"
    assert W.header_mode(1, 1 << 16) == "none"    # unchecked, statically


def test_folded_mode_roundtrips_count():
    payload = _block(np.random.default_rng(1), rows=9, words=1)
    csum = W.payload_checksum(payload)
    for count in (0, 1, 9, (1 << 16) - 1):
        w0 = W.encode_header_word0(jnp.asarray(count, jnp.int32), csum,
                                   "folded")
        assert int(W.decode_header_word0(w0, "folded")) == count


def test_corrupt_payload_raised_on_distributed_tamper(db, mesh1):
    """A bit flipped in a real packed exchange recv buffer must surface as
    CorruptPayload — never decode into a served result."""
    class OneFlip:
        def fire(self, cut, ctx, tamperable=False):
            if cut == "group_by" and tamperable:
                def tamper(p):
                    u = jax.lax.bitcast_convert_type(
                        p.reshape(-1), jnp.uint32)
                    mid = u.shape[0] // 2
                    u = u.at[mid].set(u[mid] ^ jnp.uint32(1 << 21))
                    return jax.lax.bitcast_convert_type(
                        u, jnp.int32).reshape(p.shape)
                return tamper
            return None

    with pytest.raises(W.CorruptPayload):
        B.run_distributed(QUERIES[13], db, mesh1, capacity_factor=3.0,
                          chaos=OneFlip())


# ---------------------------------------------------------------------------
# injector scheduling
# ---------------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec("meteor")
    with pytest.raises(ValueError):
        FaultSpec("transient", cut="join")
    FaultSpec("transient", cut="any")   # ok


def test_chaos_env_parsing(monkeypatch):
    for off in ("", "0", "off", "OFF", "none", "false"):
        monkeypatch.setenv("REPRO_CHAOS", off)
        assert chaos_env_seed() is None
        assert ChaosInjector.from_env() is None
    monkeypatch.delenv("REPRO_CHAOS")
    assert chaos_env_seed() is None
    monkeypatch.setenv("REPRO_CHAOS", "42")
    assert chaos_env_seed() == 42
    inj = ChaosInjector.from_env()
    assert inj.plan == FaultPlan.default(42)


def test_injector_fires_at_scheduled_visit_only():
    class Ctx:
        overflow = jnp.asarray(False)
        corrupt = jnp.asarray(False)

    inj = ChaosInjector(FaultPlan(1, (
        FaultSpec("transient", cut="exchange", index=2, attempt=3),)))
    for attempt in (1, 2):
        inj.begin_attempt(attempt)
        for _ in range(5):
            assert inj.fire("exchange", Ctx()) is None
    inj.begin_attempt(3)
    assert inj.fire("exchange", Ctx()) is None       # visit 0
    assert inj.fire("scan", Ctx()) is None           # other cut: no advance
    assert inj.fire("exchange", Ctx()) is None       # visit 1
    with pytest.raises(TransientFault):
        inj.fire("exchange", Ctx())                  # visit 2: fires
    assert [e.attempt for e in inj.events] == [3]


def test_injector_any_cut_matches_first_visit():
    class Ctx:
        overflow = jnp.asarray(False)
        corrupt = jnp.asarray(False)

    inj = ChaosInjector(FaultPlan(1, (
        FaultSpec("overflow", cut="any", index=0, attempt=1),)))
    ctx = Ctx()
    inj.fire("finalize", ctx)        # whatever cut comes first
    assert bool(ctx.overflow)
    assert inj.events[0].kind == "overflow"


def test_injector_deterministic_tamper_bit():
    """Same (seed, cut, visit, attempt) -> same flipped bit; different seed
    -> (almost surely) a different one."""
    a = ChaosInjector(FaultPlan(1, (FaultSpec("corrupt", cut="exchange"),)))
    b = ChaosInjector(FaultPlan(1, (FaultSpec("corrupt", cut="exchange"),)))
    c = ChaosInjector(FaultPlan(2, (FaultSpec("corrupt", cut="exchange"),)))
    buf = jnp.zeros((8, 4), jnp.int32)

    class Ctx:
        distributed = True
        overflow = jnp.asarray(False)
        corrupt = jnp.asarray(False)

    ta = a.fire("exchange", Ctx(), tamperable=True)
    tb = b.fire("exchange", Ctx(), tamperable=True)
    tc = c.fire("exchange", Ctx(), tamperable=True)
    assert np.array_equal(np.asarray(ta(buf)), np.asarray(tb(buf)))
    assert not np.array_equal(np.asarray(ta(buf)), np.asarray(tc(buf)))
    # exactly one bit differs from the original
    diff = np.asarray(ta(buf)).view(np.uint32) ^ np.asarray(buf).view(np.uint32)
    assert sum(bin(int(x)).count("1") for x in diff.reshape(-1)) == 1


# ---------------------------------------------------------------------------
# failure taxonomy + retry policy
# ---------------------------------------------------------------------------

def test_classification_table():
    assert classify_failure(W.CorruptPayload("x")) is FailureKind.CORRUPT
    for exc in (TypeError("t"), ValueError("v"), KeyError("k"),
                IndexError("i"), AttributeError("a"), AssertionError("s"),
                NameError("n"), ZeroDivisionError("z")):
        assert classify_failure(exc) is FailureKind.DETERMINISTIC, exc
    for exc in (TransientFault("gone"), OSError("io"), TimeoutError("slow"),
                RuntimeError("unknown")):
        assert classify_failure(exc) is FailureKind.TRANSIENT, exc


def test_retry_policy_backoff_bounded():
    p = RetryPolicy(backoff_s=0.1, backoff_mult=2.0, max_backoff_s=0.5)
    assert p.backoff(1) == pytest.approx(0.1)
    assert p.backoff(2) == pytest.approx(0.2)
    assert p.backoff(4) == pytest.approx(0.5)    # capped
    assert p.backoff(10) == pytest.approx(0.5)


def test_deterministic_error_raises_on_attempt_1(db, mesh1):
    """The old catch-all burned max_attempts re-executions on plan bugs."""
    inj = ChaosInjector(FaultPlan(1, (
        FaultSpec("deterministic", cut="scan", attempt=1),)))
    runner = QueryRunner(db, mesh1, capacity_factor=3.0, max_attempts=6,
                         chaos=inj)
    with pytest.raises(ValueError, match="plan bug"):
        runner.run(QUERIES[6])
    assert len(inj.events) == 1           # exactly one execution started
    assert runner.chaos.events[0].kind == "deterministic"


def test_corrupt_forces_wide_rerun(db, mesh1):
    inj = ChaosInjector(FaultPlan(9, (
        FaultSpec("corrupt", cut="group_by", attempt=1),)))
    runner = QueryRunner(db, mesh1, capacity_factor=3.0, max_attempts=4,
                         wire_format="narrow", chaos=inj,
                         policy=RetryPolicy(max_attempts=4, backoff_s=0.01))
    res = runner.run(QUERIES[13])
    rows = res.report.rows()
    assert [r["outcome"] for r in rows] == ["corrupt", "ok"]
    assert rows[0]["wire_format"] == "narrow"
    assert rows[1]["wire_format"] == "wide"     # never trust the bad buffer
    assert rows[0]["cut"] == "group_by"


def test_transient_retries_with_backoff(db, mesh1):
    inj = ChaosInjector(FaultPlan(4, (
        FaultSpec("transient", cut="scan", attempt=1),
        FaultSpec("transient", cut="scan", attempt=2),)))
    runner = QueryRunner(db, mesh1, capacity_factor=3.0, chaos=inj,
                         policy=RetryPolicy(max_attempts=4, backoff_s=0.01,
                                            backoff_mult=3.0))
    res = runner.run(QUERIES[6])
    rows = res.report.rows()
    assert [r["outcome"] for r in rows] == ["transient", "transient", "ok"]
    assert rows[0]["backoff_s"] == pytest.approx(0.01)
    assert rows[1]["backoff_s"] == pytest.approx(0.03)   # exponential
    assert res.attempts == 3


def test_transient_exhaustion_reraises(db, mesh1):
    inj = ChaosInjector(FaultPlan(4, tuple(
        FaultSpec("transient", cut="scan", attempt=a) for a in (1, 2))))
    runner = QueryRunner(db, mesh1, capacity_factor=3.0, chaos=inj,
                         policy=RetryPolicy(max_attempts=2, backoff_s=0.01))
    with pytest.raises(TransientFault):
        runner.run(QUERIES[6])


def _sweep_qids():
    """Fast-lane subset; the REPRO_CHAOS CI leg widens to all 22."""
    return sorted(QUERIES) if chaos_env_seed() is not None else [1, 6, 9, 13]


@pytest.mark.parametrize("infer", [True, False])
def test_chaos_differential_sweep(db, mesh1, infer):
    """The acceptance sweep: under the default seeded FaultPlan (one
    transient + one corrupt + one overflow) every query recovers to a
    result byte-identical to the fault-free run, on both planner legs, and
    the RunReport classifies every injected fault correctly."""
    for qid in _sweep_qids():
        q = QUERIES[qid].with_inference(infer)
        clean, _, ov = B.run_distributed(q, db, mesh1, capacity_factor=3.0)
        assert not ov, qid
        # start at 1.5 so the injected overflow escalates to exactly the
        # clean run's factor -- byte-identity is then apples-to-apples
        runner = QueryRunner(db, mesh1, capacity_factor=1.5, escalation=2.0,
                             chaos=ChaosInjector(FaultPlan.default(11)),
                             policy=RetryPolicy(max_attempts=6,
                                                backoff_s=0.01))
        res = runner.run(q)
        outcomes = res.report.outcomes()
        assert outcomes[:3] == ["transient", "corrupt", "overflow"], (
            qid, infer, outcomes)
        assert outcomes[-1] == "ok", (qid, infer, outcomes)
        kinds = [f.kind for f in res.report.injected]
        assert kinds == ["transient", "corrupt", "overflow"], (qid, kinds)
        assert set(clean) == set(res.result), qid
        for k in clean:
            np.testing.assert_array_equal(
                np.asarray(clean[k]), np.asarray(res.result[k]),
                err_msg=f"q{qid} {k} infer={infer}")


# ---------------------------------------------------------------------------
# skew_imbalance satellite
# ---------------------------------------------------------------------------

def test_skew_imbalance_validates_shape():
    with pytest.raises(ValueError, match="not divisible"):
        skew_imbalance(np.arange(10), k=4)
    with pytest.raises(ValueError, match="k must be"):
        skew_imbalance(np.arange(8), k=0)


def test_skew_imbalance_edges_return_neutral():
    assert skew_imbalance(np.array([]), k=1) == 1.0
    assert skew_imbalance(np.array([37]), k=1) == 1.0        # single node
    assert skew_imbalance(np.array([1, 2, 3, 4]), k=4) == 1.0
    assert skew_imbalance(np.zeros(8, np.int64), k=1) == 1.0  # no traffic


def test_skew_imbalance_values_preserved():
    counts = np.array([40, 10, 10, 10, 20, 10, 10, 10])
    assert skew_imbalance(counts, k=1) == pytest.approx(40 / 15)   # max/mean
    assert skew_imbalance(counts, k=4) == pytest.approx(70 / 60)   # [70, 50]


# ---------------------------------------------------------------------------
# lineage snapshots
# ---------------------------------------------------------------------------

def test_restore_flat_roundtrip(tmp_path):
    flat = {"a": np.arange(5), "b": np.float64(2.5).reshape(()),
            "z": np.ones((2, 3), np.int32)}
    ckpt.save(str(tmp_path), 3, flat,
              metadata={"keys": sorted(flat), "config": {"leg": 1}})
    got, meta = ckpt.restore_flat(str(tmp_path), 3)
    assert meta["config"] == {"leg": 1}
    assert sorted(got) == sorted(flat)
    for k in flat:
        np.testing.assert_array_equal(np.asarray(got[k]), flat[k])


def test_restore_flat_rejects_non_flat(tmp_path):
    ckpt.save(str(tmp_path), 0, {"a": np.arange(3)})    # no keys metadata
    with pytest.raises(ValueError, match="keys"):
        ckpt.restore_flat(str(tmp_path), 0)


def test_restore_flat_checksum(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": np.arange(64)},
              metadata={"keys": ["a"]})
    target = tmp_path / "step_0000000001" / "000000.npy"
    raw = bytearray(target.read_bytes())
    raw[-3] ^= 0x10
    target.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="checksum"):
        ckpt.restore_flat(str(tmp_path), 1)


def test_lineage_resume_skips_subtree(db, tmp_path):
    """Fail at finalize -> every exchange is durable -> the retry restores
    the topmost snapshot and re-executes only the suffix (its PlanStats
    show no exchanges re-issued)."""
    q = QUERIES[9]
    store = LineageStore(str(tmp_path / "lin"))
    inj = ChaosInjector(FaultPlan(3, (
        FaultSpec("transient", cut="finalize", attempt=1),)))
    with pytest.raises(TransientFault):
        run_resumable(q, db, store, capacity_factor=3.0, chaos=inj)
    assert store.saved >= 1
    inj.begin_attempt(2)
    r, stats, ov, reused = run_resumable(q, db, store, capacity_factor=3.0,
                                         chaos=inj)
    assert not ov and reused >= 1
    assert stats.shuffles == 0 and stats.broadcasts == 0   # subtree skipped
    r_ref, _ = B.run_reference(q, db)
    for k in set(r_ref) & set(r):
        np.testing.assert_allclose(np.asarray(r[k], np.float64),
                                   np.asarray(r_ref[k], np.float64),
                                   rtol=1e-7, err_msg=k)


def test_lineage_config_leg_invalidates(db, tmp_path):
    """A snapshot written on the narrow/inference leg must NOT be served to
    a wide or hint-dropped re-run."""
    q = QUERIES[9]
    store = LineageStore(str(tmp_path / "lin"))
    run_resumable(q, db, store, capacity_factor=3.0, wire_format="narrow")
    assert store.saved >= 1
    r, _, ov, reused = run_resumable(q, db, store, capacity_factor=3.0,
                                     wire_format="wide")
    assert reused == 0 and not ov
    r2, _, ov2, reused2 = run_resumable(q.with_inference(False), db, store,
                                        capacity_factor=3.0,
                                        wire_format="narrow")
    assert reused2 == 0 and not ov2


def test_lineage_torn_snapshot_falls_back(db, tmp_path):
    """CRC damage to a snapshot file -> silent fall back to re-execution,
    never a poisoned resume."""
    q = QUERIES[9]
    store = LineageStore(str(tmp_path / "lin"))
    r1, _, _, _ = run_resumable(q, db, store, capacity_factor=3.0)
    # corrupt every snapshot's first leaf
    for step in sorted(os.listdir(store.dir)):
        leaf = os.path.join(store.dir, step, "000000.npy")
        with open(leaf, "r+b") as f:
            f.seek(-2, 2)
            b = f.read(1)
            f.seek(-2, 2)
            f.write(bytes([b[0] ^ 0xFF]))
    r2, _, ov, reused = run_resumable(q, db, store, capacity_factor=3.0)
    assert reused == 0 and not ov
    for k in r1:
        np.testing.assert_array_equal(np.asarray(r1[k]), np.asarray(r2[k]))


def test_lineage_noop_under_jit(db, tmp_path):
    """Under jit the values are Tracers: snapshots must be skipped, not
    crash the trace."""
    store = LineageStore(str(tmp_path / "lin"))

    def q(ctx):
        ctx.lineage = store
        return QUERIES[1](ctx)

    r, _ = B.run_local(q, db, jit=True)
    assert store.saved == 0 and store.reused == 0
    r_ref, _ = B.run_reference(QUERIES[1], db)
    np.testing.assert_allclose(
        np.asarray(r["sum_qty"], np.float64),
        np.asarray(r_ref["sum_qty"], np.float64), rtol=1e-7)


# ---------------------------------------------------------------------------
# report surfacing
# ---------------------------------------------------------------------------

def test_run_report_rendered(db, mesh1, capsys):
    from repro.launch import report as rep
    runner = QueryRunner(db, mesh1, capacity_factor=3.0,
                         chaos=ChaosInjector(FaultPlan.default(2)),
                         policy=RetryPolicy(max_attempts=6, backoff_s=0.01))
    res = runner.run(QUERIES[1])
    rec = rep.run_report_record("q1", res.report)
    rec = json.loads(json.dumps(rec))      # must be JSON-able
    rep.run_report_table([rec])
    out = capsys.readouterr().out
    assert "| q1 | 1 | transient | scan |" in out
    assert "| q1 | 2 | corrupt | group_by |" in out
    assert out.strip().splitlines()[-1].split("|")[3].strip() == "ok"

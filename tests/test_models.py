"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step, output shapes, no NaNs; prefill/decode agree with the train path."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, get_config, input_specs
from repro.models import Model

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)


def _build(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg, expert_pad=1)
    params = model.init(KEY, dtype=jnp.float32)
    B, S = 2, 16
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)
    extra = None
    if cfg.frontend == "vision_patches":
        extra = {"patches": jnp.ones((B, cfg.n_prefix, cfg.d_model),
                                     jnp.float32)}
    return cfg, model, params, tokens, extra


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg, model, params, tokens, extra = _build(arch)
    B, S = tokens.shape
    logits = model.forward(params, tokens, extra=extra)
    exp_s = S + (cfg.n_prefix if cfg.frontend == "vision_patches" else 0)
    assert logits.shape == (B, exp_s, model.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_runs_and_loss_finite(arch):
    cfg, model, params, tokens, extra = _build(arch)
    batch = {"tokens": tokens, "labels": tokens}
    if extra:
        batch.update(extra)
    from repro.train import optimizer as optim
    from repro.train.trainstep import init_train_state, make_train_step
    step = jax.jit(make_train_step(model, optim.AdamWConfig(warmup_steps=1)))
    state = init_train_state(model, params)
    p2, s2, m = step(params, state, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) > 0
    # params actually changed
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, p2)
    assert max(jax.tree.leaves(d)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """prefill last-token logits == forward last-token logits; decode step
    extends without NaNs."""
    cfg, model, params, tokens, extra = _build(arch)
    B, S = tokens.shape
    exp_s = S + (cfg.n_prefix if cfg.frontend == "vision_patches" else 0)
    logits = model.forward(params, tokens, extra=extra)
    cache = model.init_cache(B, exp_s + 8, dtype=jnp.float32)
    pl, cache = model.prefill(params, tokens, cache, extra=extra)
    np.testing.assert_allclose(np.asarray(pl[:, 0], np.float32),
                               np.asarray(logits[:, -1], np.float32),
                               rtol=2e-3, atol=2e-3)
    nxt = jnp.argmax(pl, axis=-1).astype(jnp.int32)
    dl, cache = model.decode(params, nxt, cache,
                             jnp.asarray(exp_s, jnp.int32))
    assert dl.shape == (B, 1, model.padded_vocab)
    assert np.isfinite(np.asarray(dl, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_shapes(arch):
    cfg = get_config(arch)
    for shape_id in SHAPES:
        spec = input_specs(cfg, shape_id)
        assert spec, (arch, shape_id)
        for v in spec.values():
            assert all(d > 0 for d in v.shape)


def test_vocab_padding_masks_logits():
    cfg = get_config("granite_moe_3b_a800m").reduced()
    model = Model(cfg, expert_pad=1, vocab_pad=128)
    params = model.init(KEY, dtype=jnp.float32)
    tokens = jnp.zeros((1, 8), jnp.int32)
    logits = model.forward(params, tokens)
    assert logits.shape[-1] == model.padded_vocab
    assert logits.shape[-1] % 128 == 0
    pad = np.asarray(logits[..., cfg.vocab:], np.float32)
    assert (pad <= -1e29).all()


def test_moe_capacity_drop_reported():
    cfg = get_config("granite_moe_3b_a800m").reduced()
    model = Model(cfg, expert_pad=1, capacity_factor=0.25)  # force drops
    params = model.init(KEY, dtype=jnp.float32)
    tokens = jnp.zeros((2, 16), jnp.int32)
    _, aux = model._forward_aux(params, tokens)
    assert float(aux["drop_frac"]) > 0


def test_rwkv6_decode_matches_forward():
    """State-based decode must equal the parallel scan token-for-token."""
    cfg = get_config("rwkv6_3b").reduced()
    model = Model(cfg, expert_pad=1)
    params = model.init(KEY, dtype=jnp.float32)
    B, S = 1, 8
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full = model.forward(params, tokens)
    cache = model.init_cache(B, S + 4, dtype=jnp.float32)
    _, cache = model.prefill(params, tokens[:, :4], cache)
    logits = None
    for i in range(4, S):
        logits, cache = model.decode(params, tokens[:, i:i + 1], cache,
                                     jnp.asarray(i, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=3e-3, atol=3e-3)

"""ISSUE 9 acceptance sweep: all 22 TPC-H queries survive device loss
mid-query on a real (virtual) 8-device mesh, on both planner legs and both
wire formats, shrinking 8->7 and 8->4.

Run in subprocesses so the device-count XLA flag never leaks.  Each query:

  * attempt 1 dies with ``DeviceLost`` at a chaos cut point;
  * the runner shrinks the mesh to the survivors, bumps the topology
    generation and re-executes;
  * the recovered answer is BYTE-IDENTICAL to a clean run on the same
    surviving mesh (the recovery machinery adds zero numerical error) and
    matches the NumPy reference to 1e-7 — the honest cross-width gate:
    float sums at different partition counts differ in merge order by
    design (see docs/ARCHITECTURE.md §7).

The 8->7 legs arm the fault through the documented ``REPRO_CHAOS``
``lose=`` grammar (the runner's default injector), the 8->4 legs through an
explicit seeded-random plan — both resolution modes covered."""
import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, timeout=2400, chaos_env=None):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.pop("REPRO_CHAOS", None)
    if chaos_env is not None:
        env["REPRO_CHAOS"] = chaos_env
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


_PRELUDE = """
import numpy as np
from repro.core import backend as B
from repro.core.compat import make_mesh
from repro.data import tpch
from repro.distributed.chaos import ChaosInjector, FaultPlan
from repro.distributed.fault import QueryRunner, RetryPolicy, surviving_mesh
from repro.queries import QUERIES

mesh = make_mesh((8,), ("data",))
db = tpch.generate(0.005, seed=11)

def sweep(injector_for, expect_devices, infer, wire):
    for qid in sorted(QUERIES):
        q = QUERIES[qid].with_inference(infer)
        runner = QueryRunner(db, mesh, capacity_factor=3.0,
                             wire_format=wire, chaos=injector_for(qid))
        res = runner.run(q)
        outs = res.report.outcomes()
        assert outs[0] == "device_lost" and outs[-1] == "ok", (qid, outs)
        assert runner.devices == expect_devices, (qid, runner.devices)
        assert runner.topology_generation >= 1
        assert res.report.attempts[-1].devices == expect_devices
        # byte-identical to a clean run on the SAME surviving mesh
        m = surviving_mesh(mesh, runner.lost_devices, "data")
        clean, _, ov = B.run_distributed(q, db, m, capacity_factor=3.0,
                                         wire_format=wire)
        assert not ov, qid
        assert set(res.result) == set(clean), qid
        for k in res.result:
            a, b = np.asarray(res.result[k]), np.asarray(clean[k])
            assert a.dtype == b.dtype and np.array_equal(a, b), (qid, k)
        # and correct vs the reference oracle
        r_ref, _ = B.run_reference(QUERIES[qid], db)
        for k in set(r_ref) & set(res.result):
            np.testing.assert_allclose(
                np.asarray(res.result[k], np.float64),
                np.asarray(r_ref[k], np.float64), rtol=1e-7,
                err_msg=f"q{qid} {k}")
        print("q%d ok (gen %d, %d devices)"
              % (qid, runner.topology_generation, runner.devices))
"""


@pytest.mark.slow
@pytest.mark.parametrize("infer,wire", [(True, "narrow"), (False, "wide")])
def test_device_loss_8_to_7_env_grammar(infer, wire):
    """8->7: rank 3 dies at the first scan, armed via the documented
    ``REPRO_CHAOS=<seed>,lose=3@scan`` env grammar (runner default)."""
    out = _run(_PRELUDE + f"""
sweep(lambda qid: ChaosInjector.from_env(), 7, {infer!r}, {wire!r})
""", chaos_env="5,lose=3@scan")
    assert out.count("ok") == 22


@pytest.mark.slow
@pytest.mark.parametrize("infer,wire", [(True, "wide"), (False, "narrow")])
def test_device_loss_8_to_4_seeded_random(infer, wire):
    """8->4: four seeded-random ranks die at the aggregation cut — the late
    cut every query reaches (grouped plans fire it in group_by, scalar-only
    plans like Q6 in agg_scalar; finalize is never reached by scalar
    results, so it cannot cover all 22)."""
    out = _run(_PRELUDE + f"""
sweep(lambda qid: ChaosInjector(
          FaultPlan.device_loss(1000 + qid, n_lost=4, cut="group_by")),
      4, {infer!r}, {wire!r})
""")
    assert out.count("ok") == 22

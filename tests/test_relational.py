"""Unit tests: static-shape relational ops vs the NumPy reference."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import reference as REF
from repro.core import relational as R
from repro.core.table import from_numpy, to_numpy


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    n = 153
    return {
        "k": rng.integers(0, 12, n).astype(np.int64),
        "k2": rng.integers(0, 5, n).astype(np.int64),
        "v": rng.normal(size=n),
        "q": rng.integers(1, 50, n).astype(np.int64),
    }


def test_filter_matches_reference(data):
    t = from_numpy(data, capacity=256)
    got = to_numpy(R.filter_rows(t, (t["k"] < 6) & (t["q"] > 10)))
    want = REF.filter_rows(data, (data["k"] < 6) & (data["q"] > 10))
    assert got["v"].shape == want["v"].shape
    np.testing.assert_allclose(np.sort(got["v"]), np.sort(want["v"]))


def test_group_aggregate_all_ops(data):
    t = from_numpy(data, capacity=256)
    aggs = [("s", "sum", "v"), ("c", "count", None),
            ("mn", "min", "v"), ("mx", "max", "v")]
    got = to_numpy(R.group_aggregate(t, ["k", "k2"], aggs))
    want = REF.group_aggregate(data, ["k", "k2"], aggs)
    o = np.lexsort((got["k2"], got["k"]))
    ow = np.lexsort((want["k2"], want["k"]))
    for c in ("s", "c", "mn", "mx"):
        np.testing.assert_allclose(got[c][o], want[c][ow], rtol=1e-12)


def test_join_semi_anti_left(data):
    t = from_numpy(data, capacity=256)
    bcols = {"bk": np.arange(8, dtype=np.int64), "bv": np.arange(8) * 2.0}
    b = from_numpy(bcols, capacity=16)
    got = to_numpy(R.join_unique(t, b, t["k"], b["bk"], ["bv"]))
    want = REF.join_unique(data, bcols, data["k"], bcols["bk"], ["bv"])
    assert got["bv"].shape == want["bv"].shape
    np.testing.assert_allclose(np.sort(got["bv"] + got["v"]),
                               np.sort(want["bv"] + want["v"]))
    sg = to_numpy(R.semi_join(t, b, t["k"], b["bk"]))
    sw = REF.semi_join(data, bcols, data["k"], bcols["bk"])
    assert sg["k"].shape == sw["k"].shape
    ag = to_numpy(R.anti_join(t, b, t["k"], b["bk"]))
    aw = REF.anti_join(data, bcols, data["k"], bcols["bk"])
    assert ag["k"].shape == aw["k"].shape
    lg = to_numpy(R.left_join(t, b, t["k"], b["bk"], ["bv"], {"bv": -1.0}))
    lw = REF.left_join(data, bcols, data["k"], bcols["bk"], ["bv"],
                       {"bv": -1.0})
    np.testing.assert_allclose(np.sort(lg["bv"]), np.sort(lw["bv"]))


def test_join_rejects_duplicate_build_keys():
    b = {"bk": np.array([1, 1, 2], dtype=np.int64), "bv": np.zeros(3)}
    p = {"k": np.array([1, 2], dtype=np.int64)}
    with pytest.raises(ValueError):
        REF.join_unique(p, b, p["k"], b["bk"], ["bv"])


def test_sort_by_multikey(data):
    t = from_numpy(data, capacity=256)
    got = to_numpy(R.sort_by(t, [("k", True), ("v", False)]))
    want = REF.sort_by(data, [("k", True), ("v", False)])
    np.testing.assert_allclose(got["v"], want["v"])
    np.testing.assert_array_equal(got["k"], want["k"])


def test_static_shrink_overflow_flag(data):
    t = from_numpy(data, capacity=256)
    small, ov = R.static_shrink(t, 64)
    assert bool(ov) and small.capacity == 64
    big, ov2 = R.static_shrink(t, 200)
    assert not bool(ov2) and int(big.count) == len(data["k"])


def test_combine_keys_rejects_three():
    with pytest.raises(ValueError):
        R.combine_keys([jnp.arange(3)] * 3)
    with pytest.raises(ValueError):
        REF.combine_keys([np.arange(3)] * 3)


def test_limit_and_valid_mask(data):
    t = from_numpy(data, capacity=256)
    l5 = R.limit(R.sort_by(t, [("v", True)]), 5)
    got = to_numpy(l5)
    want = np.sort(data["v"])[:5]
    np.testing.assert_allclose(got["v"], want)

"""HLO collective parser + data generator invariants + train substrate."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data import jcch, tpch
from repro.distributed import hlo_analysis as ha
from repro.train import optimizer as optim

_HLO = """
ENTRY %main {
  %p0 = bf16[64,128]{1,0} parameter(0)
  %ag = bf16[512,128]{1,0} all-gather(%p0), replica_groups={}
  %ar = f32[256]{0} all-reduce(%x), to_apply=%add
  %rs.1 = f32[32,16]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = (s32[8,4]{1,0}, s32[8,4]{1,0}) all-to-all(%a, %b)
  %cp = bf16[16]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %ags = bf16[512,128]{1,0} all-gather-start(%p0)
  %agd = bf16[512,128]{1,0} all-gather-done(%ags)
}
"""


def test_parse_collectives_bytes_and_counts():
    st = ha.parse_collectives(_HLO)
    assert st.count_by_kind["all-gather"] == 2      # plain + -start
    assert st.count_by_kind["all-reduce"] == 1
    assert st.bytes_by_kind["all-gather"] == 2 * 512 * 128 * 2
    assert st.bytes_by_kind["all-reduce"] == 256 * 4
    assert st.bytes_by_kind["reduce-scatter"] == 32 * 16 * 4
    assert st.bytes_by_kind["all-to-all"] == 2 * 8 * 4 * 4
    assert st.bytes_by_kind["collective-permute"] == 16 * 2


def test_roofline_terms_pick_bottleneck():
    r = ha.roofline_terms(hlo_flops=197e12, hlo_bytes=1e9,
                          collective_bytes=1e9, n_chips=1,
                          model_flops=98.5e12)
    assert r["bottleneck"] == "compute"
    assert r["useful_flop_frac"] == pytest.approx(0.5)
    assert 0 < r["roofline_frac"] <= 1.0
    r2 = ha.roofline_terms(1e12, 819e9 * 2, 0.0, 1)
    assert r2["bottleneck"] == "memory"


def test_tpch_referential_integrity():
    db = tpch.generate(0.004, seed=3)
    li = db.tables["lineitem"]
    ps = db.tables["partsupp"]
    pairs_ps = set(zip(ps["ps_partkey"].tolist(), ps["ps_suppkey"].tolist()))
    pairs_li = set(zip(li["l_partkey"][:2000].tolist(),
                       li["l_suppkey"][:2000].tolist()))
    assert pairs_li <= pairs_ps
    ok = db.tables["orders"]["o_orderkey"]
    assert li["l_orderkey"].min() >= ok.min()
    assert li["l_orderkey"].max() <= ok.max()
    # a third of customers have no orders (Q13/Q22 depend on this)
    n_c = len(db.tables["customer"]["c_custkey"])
    missing = n_c - len(np.unique(db.tables["orders"]["o_custkey"]))
    assert missing > 0.2 * n_c
    # phone country code rule (Q22)
    c = db.tables["customer"]
    np.testing.assert_array_equal(c["c_phone_cc"], c["c_nationkey"] + 10)


def test_jcch_skew_concentrates_keys():
    uni = tpch.generate(0.004, seed=3)
    skw = jcch.generate(0.004, seed=3, skew=0.3)
    def top_share(db):
        _, counts = np.unique(db.tables["lineitem"]["l_partkey"],
                              return_counts=True)
        counts.sort()
        return counts[-5:].sum() / counts.sum()
    assert top_share(skw) > 3 * top_share(uni)


def test_adamw_descends_quadratic():
    cfg = optim.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                            weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = optim.init_state(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
        return optim.apply_update(cfg, p, g, s)

    for _ in range(50):
        params, state, m = step(params, state)
    assert float(jnp.abs(params["w"]).max()) < 0.5
    assert float(m["grad_norm"]) < 2.0


def test_int8_error_feedback_unbiased():
    grads = {"w": jnp.asarray(np.random.default_rng(0).normal(size=256),
                              jnp.float32)}
    resid = optim.init_error_feedback(grads)
    acc = jnp.zeros(256)
    for _ in range(20):
        q, resid = optim.compress_int8_ef(grads, resid)
        acc = acc + q["w"]
    # over steps, quantized sum approaches true sum (error feedback)
    np.testing.assert_allclose(np.asarray(acc) / 20, np.asarray(grads["w"]),
                               atol=2e-2)


def test_microbatched_step_matches_plain():
    """Grad accumulation over microbatches is numerically identical."""
    from repro.configs import get_config
    from repro.models import Model
    from repro.train.trainstep import init_train_state, make_train_step

    cfg = get_config("phi3_mini_3_8b").reduced()
    model = Model(cfg, expert_pad=1)
    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    state = init_train_state(model, params)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    p1, _, m1 = jax.jit(make_train_step(model, optim.AdamWConfig()))(
        params, state, batch)
    p4, _, m4 = jax.jit(make_train_step(model, optim.AdamWConfig(),
                                        microbatches=4))(params, state, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-5
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 5e-5

"""Planner differential suite: inferred hints vs the deleted hand hints,
hinted-vs-unhinted byte identity, exchange-placement validation, and the
hash-join bucket overflow -> ctx.overflow -> capacity-escalation wiring.
"""
import numpy as np
import pytest

from repro.core import backend as B
from repro.core import plan as P
from repro.core import planner as PL
from repro.data import tpch
from repro.queries import QUERIES


@pytest.fixture(scope="module")
def db():
    return tpch.generate(0.005, seed=11)


# ---------------------------------------------------------------------------
# inferred hints at least as tight as the deleted hand hints
# ---------------------------------------------------------------------------

# The hand-threaded hints PR 2 carried on the final group-by of each of these
# plans, deleted in this PR: {qid: (groups_hint, sum(key_bits))}.  The planner
# must prove bounds no looser than what the hand plans claimed.
_HAND_HINTS = {
    1: (8, 3),     # dict_bits(l_returnflag)+dict_bits(l_linestatus)
    4: (8, 3),     # dict_bits(o_orderpriority)
    5: (32, 5),    # nationkey < 25
    7: (16, 13),   # grp < 25*25*8
    8: (16, 11),   # o_year from the 1970-2005 LUT
    9: (512, 9),   # grp = nationkey*16 + (year-1992) < 400
    12: (16, 3),   # dict_bits(l_shipmode)
    22: (40, 6),   # c_phone_cc = nationkey + 10 < 35
}


def _final_group_by(qid):
    gbs = [n for n in PL.walk(QUERIES[qid].plan)
           if isinstance(n, P.GroupBy) and n.final]
    assert len(gbs) == 1, qid
    return gbs[0]


@pytest.mark.parametrize("qid", sorted(_HAND_HINTS))
def test_inferred_hints_at_least_as_tight_as_hand_hints(db, qid):
    hand_gh, hand_bits = _HAND_HINTS[qid]
    kb, gh = QUERIES[qid].info(db).hints_for(_final_group_by(qid))
    assert kb is not None, f"q{qid}: planner failed to prove key_bits"
    assert sum(kb) <= hand_bits, \
        f"q{qid}: inferred bits {kb} looser than hand {hand_bits}"
    assert gh is not None, f"q{qid}: planner failed to prove groups_hint"
    assert gh <= hand_gh, \
        f"q{qid}: inferred groups_hint {gh} looser than hand {hand_gh}"


@pytest.mark.parametrize("qid", sorted(_HAND_HINTS))
def test_inferred_bits_unlock_direct_path(db, qid):
    """Every previously-hinted plan still takes the sortless direct path."""
    from repro.core.relational import DIRECT_AGG_BITS_MAX
    kb, _ = QUERIES[qid].info(db).hints_for(_final_group_by(qid))
    assert kb is not None and sum(kb) <= DIRECT_AGG_BITS_MAX


def test_no_hand_key_bits_left_in_query_code():
    """The builder has no key_bits parameter, so plans cannot state widths;
    double-check no plan smuggles one through groups_hint-less GroupBy."""
    import inspect
    from repro import queries
    for mod in (queries.q01_08, queries.q09_15, queries.q16_22):
        assert "key_bits=" not in inspect.getsource(mod)


# ---------------------------------------------------------------------------
# hinted (inference on) == unhinted (inference off), byte for byte
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qid", sorted(QUERIES))
def test_inference_on_off_byte_identical(db, qid):
    """The compiled hinted path and the conservative unhinted path must agree
    bit for bit on the local backend — the planner cannot silently diverge
    from the legacy eager semantics.

    Byte identity holds per aggregation engine: under REPRO_AGG_KERNEL=1 the
    hinted direct path sums on the (interpret-mode) MXU one-hot kernel while
    the unhinted path uses segment_sum, so that leg compares at the same
    rtol=1e-9 the kernel-vs-oracle suite (test_aggregate_paths) pins."""
    from repro.core.relational import agg_kernel_default
    r_on, s_on = B.run_local(QUERIES[qid].with_inference(True), db)
    r_off, s_off = B.run_local(QUERIES[qid].with_inference(False), db)
    assert set(r_on) == set(r_off)
    for k in r_on:
        if agg_kernel_default():
            np.testing.assert_allclose(
                np.asarray(r_on[k], np.float64),
                np.asarray(r_off[k], np.float64),
                rtol=1e-9, err_msg=f"q{qid} {k}")
        else:
            np.testing.assert_array_equal(r_on[k], r_off[k],
                                          err_msg=f"q{qid} {k}")
    assert s_on.counts() == s_off.counts()   # hints never move exchanges


@pytest.mark.parametrize("qid", sorted(QUERIES))
def test_builder_plans_match_reference(db, qid):
    """All 22 builder plans match the NumPy oracle (local backend; the
    distributed leg lives in test_distributed.py)."""
    r_ref, _ = B.run_reference(QUERIES[qid], db)
    r_loc, _ = B.run_local(QUERIES[qid].with_inference(True), db)
    n = len(next(iter(r_ref.values())))
    for k in set(r_ref) & set(r_loc):
        assert len(r_loc[k]) == n
        np.testing.assert_allclose(np.asarray(r_loc[k], np.float64),
                                   np.asarray(r_ref[k], np.float64),
                                   rtol=1e-7, err_msg=f"q{qid} {k}")


# ---------------------------------------------------------------------------
# exchange-placement validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qid", sorted(QUERIES))
def test_paper_placement_validates_clean(db, qid):
    """The derived placement agrees with the paper's explicit placement on
    all 22 plans (Q11's deviation is a count difference vs the paper's table,
    not a placement inconsistency)."""
    assert QUERIES[qid].validate(db) == []


def test_validation_flags_redundant_exchange(db):
    # lineitem is partitioned by l_orderkey: a shuffle to it is removable,
    # and a grouped shuffle over a co-partitioned key likewise
    plan = P.scan("lineitem").shuffle("l_orderkey").finalize()
    notes = PL.validate(plan, db)
    assert any("removable" in n for n in notes), notes
    plan2 = P.scan("lineitem").group_by(
        ["l_orderkey"], [("n", "count", None)],
        exchange="shuffle").finalize()
    notes2 = PL.validate(plan2, db)
    assert any("removable" in n for n in notes2), notes2


def test_validation_flags_non_disjoint_local_group(db):
    # grouping lineitem by suppkey locally while partitioned by orderkey
    # produces per-device partials consumed as a global result -> flagged
    plan = P.scan("lineitem").group_by(
        ["l_suppkey"], [("n", "count", None)], exchange="local").finalize()
    notes = PL.validate(plan, db)
    assert any("span devices" in n for n in notes), notes


def test_validation_flags_missing_join_exchange(db):
    # joining two tables partitioned on unrelated keys without an exchange
    plan = P.scan("lineitem").join(P.scan("customer"), "l_suppkey",
                                   "c_custkey", []).finalize()
    notes = PL.validate(plan, db)
    assert any("not co-partitioned" in n for n in notes), notes


def test_validation_accepts_membership_only_partial_group(db):
    # the Q20 idiom: a partial local group-by consumed only through
    # broadcast -> semi (key membership) is globally exact -> no flag
    sk = P.scan("lineitem").group_by(["l_suppkey"], [("n", "count", None)],
                                     exchange="local")
    skb = sk.select("l_suppkey").broadcast()
    s = P.scan("supplier").semi(skb, "s_suppkey", "l_suppkey")
    assert PL.validate(s.finalize(), db) == []


def test_static_counts_need_no_database():
    """Table-4 derivation is pure IR analysis."""
    plan = P.scan("lineitem").select("l_orderkey").broadcast().finalize()
    assert PL.static_plan_stats(plan) == {
        "shuffles": 0, "broadcasts": 1, "final_gathers": 1, "allreduces": 0}


# ---------------------------------------------------------------------------
# bound propagation unit checks
# ---------------------------------------------------------------------------

def test_filter_refinement_bounds_year_expression(db):
    info = QUERIES[7].info(db)
    kb, gh = info.hints_for(_final_group_by(7))
    # s/c_nationkey filtered to {FRANCE, GERMANY} and l_year to 1995-1996:
    # the packed grp domain collapses to at most 2*2*2 = 8 groups
    assert gh <= 8
    assert sum(kb) <= 11


def test_pinned_query_keeps_planner_surface(db):
    """with_inference() pins the mode but must keep the CompiledQuery surface
    (the fault runner's hint-drop recovery re-pins via with_inference)."""
    p = QUERIES[13].with_inference(True)
    assert p.static_counts() == QUERIES[13].static_counts()
    q = p.with_inference(False)
    r_on, _ = B.run_local(p, db)
    r_off, _ = B.run_local(q, db)
    for k in r_on:
        np.testing.assert_allclose(np.asarray(r_on[k], np.float64),
                                   np.asarray(r_off[k], np.float64),
                                   rtol=1e-9)


def test_stats_override_is_scoped(db):
    """planner.stats_override must restore actual-scale stats and drop every
    dependent PlanInfo on both entry and exit (the SF=1000 dry-run contract)."""
    from repro.core.planner import ColStats, column_stats, stats_override
    pre = column_stats(db)["o_custkey"]
    QUERIES[10].info(db)                      # warm a dependent PlanInfo
    with stats_override(db, {**column_stats(db),
                             "o_custkey": ColStats(1, 1 << 27, 1 << 27)}):
        assert column_stats(db)["o_custkey"].hi == 1 << 27
        gb = [n for n in PL.walk(QUERIES[10].plan)
              if isinstance(n, P.GroupBy)][0]
        kb, _ = QUERIES[10].info(db).hints_for(gb)
        assert kb is None                     # 28 bits: no direct path
    assert column_stats(db)["o_custkey"] == pre
    gb = [n for n in PL.walk(QUERIES[10].plan) if isinstance(n, P.GroupBy)][0]
    kb, _ = QUERIES[10].info(db).hints_for(gb)
    assert kb is not None                     # re-inferred at actual scale


def test_isin_rejects_empty_set_at_build_time():
    with pytest.raises(ValueError, match="empty value set"):
        P.isin(P.col("x"), [])


def test_expr_has_no_truth_value():
    """`a <= x < b` or `p and q` would silently drop a conjunct via implicit
    bool(); the builder must refuse instead of compiling a wrong predicate."""
    with pytest.raises(TypeError, match="truth value"):
        bool(P.col("l_shipdate") <= 42)
    with pytest.raises(TypeError, match="truth value"):
        (P.col("a") > 0) and (P.col("b") > 0)          # noqa: B015
    with pytest.raises(TypeError):
        1 <= P.col("l_shipdate") < 9999                # chained comparison


def test_explain_renders(db):
    text = QUERIES[1].explain(db)
    assert "group_by['l_returnflag', 'l_linestatus']" in text
    assert "direct (sortless)" in text


# ---------------------------------------------------------------------------
# hash-join bucket overflow -> ctx.overflow -> capacity escalation
# ---------------------------------------------------------------------------

def test_hash_bucket_overflow_sets_ctx_overflow(db):
    """A starved capacity factor overflows the hash-join bucket table; the
    flag must surface on ctx.overflow (run_local asserts on it) instead of
    failing locally inside kernels/hash_probe, and the fault-runner-style
    escalation loop must clear it and reproduce the oracle's answer."""
    with pytest.raises(AssertionError, match="overflow"):
        B.run_local(QUERIES[9], db, join_method="hash", capacity_factor=0.25)

    factor, result = 0.25, None
    for _ in range(6):                       # QueryRunner's discipline
        try:
            result, _ = B.run_local(QUERIES[9], db, join_method="hash",
                                    capacity_factor=factor)
            break
        except AssertionError:
            factor *= 2.0
    assert result is not None and factor > 0.25
    r_ref, _ = B.run_reference(QUERIES[9], db)
    np.testing.assert_allclose(np.asarray(result["sum_profit"], np.float64),
                               np.asarray(r_ref["sum_profit"], np.float64),
                               rtol=1e-7)


def test_bucket_cap_scales_with_capacity_factor(db):
    tables = B._np_db_to_tables(db)
    assert B.LocalContext(db, tables).bucket_cap() == 16      # historic cap
    assert B.LocalContext(db, tables,
                          capacity_factor=0.25).bucket_cap() == 2
    assert B.LocalContext(db, tables,
                          capacity_factor=8.0).bucket_cap() == 64

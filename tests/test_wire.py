"""Wire-format tests: stats-driven exchange payload compression.

Four layers of assertion:

  * **Layout** — the wide layout reproduces the legacy packing exactly; the
    narrow layout never exceeds it; mode selection follows the documented
    lane rules.
  * **Round-trip** — pack/unpack is lossless on every valid row across all
    dtypes x widths x masked tables (hypothesis), with a statically-false
    overflow flag when the bounds are truthful.
  * **Overflow contract** — lying bounds must trip the overflow flag (pack
    level, exchange level under a real collective, and a full distributed
    query with planner statistics overridden) — never silently truncate.
  * **Static == runtime** — the IR-derived wire descriptors
    (``planner.static_wire_stats``) equal the ``ExchangeStats`` every backend
    logs, entry for entry, and the distributed narrow format is byte-
    identical to wide on real exchanges (the full 22-query x 8-device sweep
    is the slow leg in tests/test_distributed.py).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import backend as B
from repro.core import planner as PL
from repro.core import wire as W
from repro.core.compat import make_mesh
from repro.core.relational import filter_rows
from repro.core.table import from_numpy
from repro.data import tpch
from repro.queries import QUERIES


@pytest.fixture(scope="module")
def db():
    return tpch.generate(0.005, seed=11)


@pytest.fixture(scope="module")
def mesh1():
    return make_mesh((1,), ("data",))


def _mktable(rng, n=80, cap=96):
    cols = {
        "k64": rng.integers(0, 200, n).astype(np.int64),
        "wide64": (rng.integers(0, 1 << 40, n)).astype(np.int64),
        "mid64": (rng.integers(100_000, 1 << 25, n)).astype(np.int64),
        "i32": rng.integers(-50, 900, n).astype(np.int32),
        "f64": rng.normal(size=n),
        "f32": rng.normal(size=n).astype(np.float32),
        "b": rng.integers(0, 2, n).astype(bool),
        "c": np.full(n, -7, np.int64),
    }
    return cols, from_numpy(cols, capacity=cap)


def _true_bounds(cols):
    return {n: (int(v.min()), int(v.max())) for n, v in cols.items()
            if np.issubdtype(v.dtype, np.integer)}


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------

def test_wide_layout_matches_legacy_packing():
    """Wide = one word per 4 logical bytes, bool widened, sorted-name order."""
    dt = {"a": np.dtype(np.int64), "b": np.dtype(bool),
          "c": np.dtype(np.float64), "d": np.dtype(np.int32)}
    fmt = W.plan_wire_format(dt, dt, bounds=None, narrow=False)
    assert fmt.words == 2 + 1 + 2 + 1
    modes = {c.name: (c.mode, c.word) for c in fmt.cols}
    assert modes == {"a": ("split", 0), "b": ("word", 2),
                     "c": ("split", 3), "d": ("word", 5)}
    assert fmt.row_wire_bytes == 24 and fmt.row_logical_bytes == 21


def test_narrow_mode_selection_and_lane_sharing():
    dt = {"dict8": np.dtype(np.int32), "date16": np.dtype(np.int64),
          "key32": np.dtype(np.int64), "flag": np.dtype(bool),
          "price": np.dtype(np.float64), "konst": np.dtype(np.int64)}
    bounds = {"dict8": (0, 24), "date16": (8000, 10500),
              "key32": (1, 1 << 20), "konst": (5, 5)}
    fmt = W.plan_wire_format(dt, dt, bounds, narrow=True)
    modes = {c.name: c.mode for c in fmt.cols}
    assert modes == {"dict8": "lane8", "date16": "lane16", "key32": "u32",
                     "flag": "lane8", "price": "split", "konst": "const"}
    # 16-bit lane + two 8-bit lanes share ONE word; const ships nothing
    lane_words = {c.word for c in fmt.cols if c.mode.startswith("lane")}
    assert len(lane_words) == 1
    assert fmt.words == 1 + 1 + 2        # lanes + u32 + f64 split
    assert fmt.row_wire_bytes == 16 and fmt.row_logical_bytes == 37


def test_narrow_never_exceeds_wide():
    rng = np.random.default_rng(0)
    cols, _ = _mktable(rng)
    dt = {n: v.dtype for n, v in cols.items()}
    for bounds in (None, {}, _true_bounds(cols)):
        nf = W.plan_wire_format(cols, dt, bounds, narrow=True)
        wf = W.plan_wire_format(cols, dt, bounds, narrow=False)
        assert nf.words <= wf.words
        assert nf.row_logical_bytes == wf.row_logical_bytes


# ---------------------------------------------------------------------------
# round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("narrow", [True, False])
@pytest.mark.parametrize("masked", [True, False])
def test_pack_unpack_roundtrip_all_modes(seed, narrow, masked):
    rng = np.random.default_rng(seed)
    cols, t = _mktable(rng)
    if masked:
        t = filter_rows(t, t["k64"] < 150)
    fmt = W.plan_wire_format(cols, {n: v.dtype for n, v in cols.items()},
                             _true_bounds(cols), narrow=narrow)
    buf, overflow = W.pack_table(t, fmt)
    assert not bool(overflow), "truthful bounds must never overflow"
    back = W.unpack_table(buf, fmt)
    m = np.asarray(t.valid_mask())
    for n in cols:
        np.testing.assert_array_equal(np.asarray(back[n])[m],
                                      np.asarray(t[n])[m], err_msg=n)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                                     # pragma: no cover
    HAVE_HYP = False


if HAVE_HYP:
    @st.composite
    def bounded_tables(draw):
        n = draw(st.integers(1, 60))
        rng = np.random.default_rng(draw(st.integers(0, 2**31)))
        hi8 = draw(st.integers(0, 255))
        hi16 = draw(st.integers(256, 65_535))
        lo = draw(st.integers(-(1 << 40), 1 << 40))
        span = draw(st.integers(0, 1 << 33))
        cols = {
            "a": rng.integers(0, hi8 + 1, n).astype(np.int64),
            "b": rng.integers(0, hi16 + 1, n).astype(np.int32)
            if hi16 <= (1 << 31) - 1 else
            rng.integers(0, hi16 + 1, n).astype(np.int64),
            "c": rng.integers(lo, lo + span + 1, n).astype(np.int64),
            "v": rng.normal(size=n),
            "f": rng.normal(size=n).astype(np.float32),
            "m": rng.integers(0, 2, n).astype(bool),
        }
        mask_frac = draw(st.floats(0.0, 1.0))
        return cols, mask_frac, rng

    @settings(max_examples=40, deadline=None)
    @given(bounded_tables(), st.booleans())
    def test_roundtrip_property(args, narrow):
        """Lossless on valid rows for every dtype/width/mask combination."""
        cols, mask_frac, rng = args
        n = len(cols["a"])
        t = from_numpy(cols, capacity=max(8, n + 3))
        keep = rng.random(t.capacity) >= mask_frac
        t = filter_rows(t, jnp.asarray(keep))
        fmt = W.plan_wire_format(cols, {k: v.dtype for k, v in cols.items()},
                                 _true_bounds(cols), narrow=narrow)
        buf, overflow = W.pack_table(t, fmt)
        assert not bool(overflow)
        back = W.unpack_table(buf, fmt)
        m = np.asarray(t.valid_mask())
        for name in cols:
            np.testing.assert_array_equal(np.asarray(back[name])[m],
                                          np.asarray(t[name])[m],
                                          err_msg=f"{name} narrow={narrow}")


# ---------------------------------------------------------------------------
# overflow contract (lying bounds)
# ---------------------------------------------------------------------------

def test_lying_bounds_trip_overflow_at_pack():
    rng = np.random.default_rng(7)
    cols, t = _mktable(rng)
    bounds = _true_bounds(cols)
    lo, hi = bounds["k64"]
    for lie in [(lo, max(lo, hi // 4)), (lo + 1, hi), (hi + 1, hi + 2)]:
        bad = dict(bounds)
        bad["k64"] = lie
        fmt = W.plan_wire_format(cols, {n: v.dtype for n, v in cols.items()},
                                 bad, narrow=True)
        _, overflow = W.pack_table(t, fmt)
        assert bool(overflow), f"lie {lie} must trip overflow"


def test_lying_bounds_only_checked_on_valid_rows():
    """Garbage in masked rows must NOT trip the range check."""
    rng = np.random.default_rng(8)
    cols, t = _mktable(rng)
    # mask out every row whose k64 exceeds 20, then claim (0, 20): truthful
    # for the surviving rows even though masked rows violate it
    t = filter_rows(t, t["k64"] <= 20)
    bounds = dict(_true_bounds(cols))
    bounds["k64"] = (0, 20)
    fmt = W.plan_wire_format(cols, {n: v.dtype for n, v in cols.items()},
                             bounds, narrow=True)
    buf, overflow = W.pack_table(t, fmt)
    assert not bool(overflow)
    back = W.unpack_table(buf, fmt)
    m = np.asarray(t.valid_mask())
    np.testing.assert_array_equal(np.asarray(back["k64"])[m],
                                  np.asarray(t["k64"])[m])


def test_lying_bounds_trip_ctx_overflow_distributed(db, mesh1):
    """A full distributed query with a lying planner statistic must surface
    ctx.overflow (the fault runner's re-execution signal), never silently
    truncate: Q3's broadcast ships c_custkey, whose claimed width we break."""
    stats = dict(PL.column_stats(db))
    real = stats["c_custkey"]
    stats["c_custkey"] = PL.ColStats(real.lo, max(real.lo, real.hi // 8), None)
    with PL.stats_override(db, stats):
        _, _, ov = B.run_distributed(QUERIES[3].with_inference(True), db,
                                     mesh1, capacity_factor=3.0,
                                     wire_format="narrow")
    assert ov, "lying wire bounds must raise the overflow flag"
    # sanity: with honest statistics the same plan runs clean
    _, _, ov = B.run_distributed(QUERIES[3].with_inference(True), db, mesh1,
                                 capacity_factor=3.0, wire_format="narrow")
    assert not ov


# ---------------------------------------------------------------------------
# static == runtime, narrow == wide
# ---------------------------------------------------------------------------

def _entries(stats):
    return [(e.kind, e.wire, e.row_wire_bytes, e.row_logical_bytes)
            for e in stats.log]


def _static(qid, db, narrow):
    return [(d["kind"], d["wire"], d["row_wire_bytes"],
             d["row_logical_bytes"])
            for d in QUERIES[qid].static_wire(db, narrow=narrow)]


@pytest.mark.parametrize("qid", sorted(QUERIES))
def test_static_wire_stats_equal_reference_runtime(db, qid):
    """IR-derived wire descriptors == what execution records, both formats."""
    for wf in ("narrow", "wide"):
        _, stats = B.run_reference(QUERIES[qid].with_inference(True),
                                   db, wire_format=wf)
        assert _entries(stats) == _static(qid, db, wf == "narrow"), (qid, wf)


@pytest.mark.parametrize("qid", [2, 5, 9, 18, 22])
def test_static_wire_stats_equal_local_runtime(db, qid):
    for wf in ("narrow", "wide"):
        _, stats = B.run_local(QUERIES[qid].with_inference(True), db,
                               wire_format=wf)
        assert _entries(stats) == _static(qid, db, wf == "narrow"), (qid, wf)


@pytest.mark.parametrize("qid", [3, 5, 9, 18])
def test_distributed_narrow_equals_wide_and_static(db, mesh1, qid):
    """Real collectives (1-device mesh): the narrow format is byte-identical
    to wide, matches the NumPy oracle, logs ONE collective per packed
    exchange (fused counts header), and reports the static wire bytes."""
    q = QUERIES[qid].with_inference(True)
    r_ref, _ = B.run_reference(q, db)
    r_n, s_n, ov_n = B.run_distributed(q, db, mesh1, capacity_factor=3.0,
                                       wire_format="narrow")
    r_w, s_w, ov_w = B.run_distributed(q, db, mesh1, capacity_factor=3.0,
                                       wire_format="wide")
    assert not ov_n and not ov_w
    assert set(r_n) == set(r_w)
    for k in r_n:
        np.testing.assert_array_equal(r_n[k], r_w[k], err_msg=f"q{qid} {k}")
    for k in set(r_ref) & set(r_n):
        np.testing.assert_allclose(np.asarray(r_n[k], np.float64),
                                   np.asarray(r_ref[k], np.float64),
                                   rtol=1e-7, err_msg=f"q{qid} {k}")
    assert _entries(s_n) == _static(qid, db, True), qid
    assert _entries(s_w) == _static(qid, db, False), qid
    # metadata round fused into the payload: one collective per exchange
    assert all(e.collectives == 1 for e in s_n.log), \
        [(e.kind, e.collectives) for e in s_n.log]
    # wire bytes on the wire really shrank vs the wide leg
    assert sum(e.message_bytes for e in s_n.log) < \
        sum(e.message_bytes for e in s_w.log)


# ---------------------------------------------------------------------------
# Hockney-driven packing skip (REPRO_HOCKNEY)
# ---------------------------------------------------------------------------

def test_hockney_skip_thresholds(monkeypatch):
    from repro.core import perfmodel as PM
    monkeypatch.delenv("REPRO_HOCKNEY", raising=False)
    assert PM.hockney_from_env() is None
    assert not W.hockney_skip(24)
    # 10us latency, 1ns/B: a 4096-row x 24B message costs ~98us on the wire
    # -> bandwidth-bound, packing pays
    monkeypatch.setenv("REPRO_HOCKNEY", "1e-5,1e-9")
    assert not W.hockney_skip(24)
    # 1ms latency: the same message sits below the half-bandwidth point
    monkeypatch.setenv("REPRO_HOCKNEY", "1e-3,1e-9")
    assert W.hockney_skip(24)
    # explicit msg_rows field: one-row messages are latency-bound even at 10us
    monkeypatch.setenv("REPRO_HOCKNEY", "1e-5,1e-9,1")
    assert W.hockney_skip(24)


def test_hockney_latency_bound_message_ships_wide(monkeypatch):
    dt = {"dict8": np.dtype(np.int32), "key32": np.dtype(np.int64)}
    bounds = {"dict8": (0, 24), "key32": (1, 1 << 20)}
    monkeypatch.setenv("REPRO_HOCKNEY", "1.0,1e-9")
    fmt = W.plan_wire_format(dt, dt, bounds, narrow=True)
    assert not fmt.narrow and fmt.row_wire_bytes == 12   # wide: 1 + 2 words
    monkeypatch.delenv("REPRO_HOCKNEY")
    fmt = W.plan_wire_format(dt, dt, bounds, narrow=True)
    assert fmt.narrow and fmt.row_wire_bytes < 12


def test_hockney_skip_static_equals_runtime(db, monkeypatch):
    """The skip is priced from per-row widths + the env model alone, so the
    IR-derived report and every backend reach the same wide verdict."""
    monkeypatch.setenv("REPRO_HOCKNEY", "1.0,1e-9")
    for qid in (3, 9):
        _, stats = B.run_reference(QUERIES[qid].with_inference(True), db,
                                   wire_format="narrow")
        got = _entries(stats)
        assert got == _static(qid, db, True), qid
        assert got and all(e[1] == "wide" for e in got), got


def test_unpacked_mode_keeps_metadata_round(db, mesh1):
    """Paper-faithful per-column exchange: one collective per column PLUS the
    size-metadata round (the §2.3 baseline the fused header removes)."""
    _, s_col, ov = B.run_distributed(QUERIES[9].with_inference(True), db,
                                     mesh1, capacity_factor=3.0,
                                     packed_exchange=False)
    assert not ov
    for e in s_col.log:
        if e.kind == "broadcast_p2p":
            continue
        assert e.collectives > 1, (e.kind, e.collectives)
        assert e.wire == "wide"

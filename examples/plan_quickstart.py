"""Builder-API quickstart: TPC-H Q6 as a lazy logical plan, end to end.

Shows the whole lifecycle: build a plan DAG with the fluent builder, inspect
what the planner infers (key widths, group bounds, derived exchange counts,
placement validation), then compile and run the SAME plan object on the
NumPy reference backend and the JAX local backend.

    PYTHONPATH=src python examples/plan_quickstart.py
"""
import numpy as np

from repro.core import backend as B
from repro.core.plan import col, result, scan
from repro.core.planner import compile_query
from repro.core.table import days
from repro.data import tpch


def q6_plan():
    """TPC-H Q6: revenue change from hypothetical discount elimination.

    A pure scan-filter-aggregate — one allreduce, zero other exchanges."""
    l = scan("lineitem").filter(
        (col("l_shipdate") >= days("1994-01-01")) &
        (col("l_shipdate") < days("1995-01-01")) &
        (col("l_discount") >= 0.05) & (col("l_discount") <= 0.07) &
        (col("l_quantity") < 24))
    s = l.agg_scalar([("revenue", "sum",
                       col("l_extendedprice") * col("l_discount"))])
    return result(revenue=s["revenue"])


def main():
    db = tpch.generate(0.01, seed=7)
    q6 = compile_query(q6_plan, name="q6")

    # the plan is data: inspect it before running anything
    print("static exchange counts (no execution):", q6.static_counts())
    print("placement validation notes:", q6.validate(db) or "clean")
    print(q6.explain(db))

    # one plan object, every backend
    r_ref, _ = B.run_reference(q6, db)
    r_loc, stats = B.run_local(q6, db)
    print(f"\nreference revenue = {float(r_ref['revenue'][0]):,.2f}")
    print(f"local     revenue = {float(r_loc['revenue'][0]):,.2f}"
          f"   (allreduces={stats.allreduces})")
    np.testing.assert_allclose(np.asarray(r_loc["revenue"], np.float64),
                               np.asarray(r_ref["revenue"], np.float64),
                               rtol=1e-7)

    # a grouped example: the planner proves the hints Q1 used to hand-carry
    from repro.queries import QUERIES
    print("\n" + QUERIES[1].explain(db))
    r1, _ = B.run_local(QUERIES[1], db)
    flags = db.dicts["l_returnflag"][r1["l_returnflag"].astype(int)]
    print("Q1 return flags decoded:", list(flags))


if __name__ == "__main__":
    main()

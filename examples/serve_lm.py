"""Batched serving driver: prefill a batch of prompts, decode with sampling.

    PYTHONPATH=src python examples/serve_lm.py [--arch rwkv6_3b] [--tokens 32]
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6_3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = Model(cfg, expert_pad=1)
    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    print(f"serving {cfg.name} (reduced) batch={args.batch}")

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    max_len = args.prompt_len + args.tokens + 8
    extra = None
    if cfg.frontend == "vision_patches":
        extra = {"patches": jnp.ones(
            (args.batch, cfg.n_prefix, cfg.d_model), jnp.float32)}
        max_len += cfg.n_prefix

    cache = model.init_cache(args.batch, max_len, dtype=jnp.float32)
    prefill = jax.jit(lambda p, t, c: model.prefill(p, t, c, extra=extra))
    decode = jax.jit(model.decode)

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts, cache)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    pos0 = args.prompt_len + (cfg.n_prefix if extra else 0)

    key = jax.random.PRNGKey(1)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(args.tokens - 1):
        logits, cache = decode(params, tok, cache,
                               jnp.asarray(pos0 + i, jnp.int32))
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(
            sub, logits[:, -1] / args.temperature, axis=-1
        )[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.concatenate(out, axis=1)
    print(f"prefill: {t_prefill * 1e3:.1f} ms for "
          f"{args.batch}x{args.prompt_len} tokens")
    print(f"decode : {t_decode * 1e3:.1f} ms for {args.tokens} steps "
          f"({args.batch * args.tokens / t_decode:.1f} tok/s batch)")
    print("sampled token ids (first sequence):", gen[0][:16].tolist())


if __name__ == "__main__":
    main()

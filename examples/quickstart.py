"""Quickstart: generate TPC-H, run queries on the tensor engine, read results.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import backend as B
from repro.data import tpch
from repro.queries import QUERIES


def main():
    print("Generating TPC-H SF=0.01 ...")
    db = tpch.generate(0.01, seed=7)
    for name, t in db.tables.items():
        print(f"  {name:10s} {len(next(iter(t.values()))):>8,d} rows")

    for qid in (1, 6, 19):
        result, stats = B.run_local(QUERIES[qid], db)
        print(f"\nQ{qid}  (shuffles={stats.shuffles} "
              f"broadcasts={stats.broadcasts})")
        cols = list(result)[:6]
        print("  " + " | ".join(f"{c:>16s}" for c in cols))
        n = len(next(iter(result.values())))
        for i in range(min(n, 5)):
            row = []
            for c in cols:
                v = result[c][i]
                row.append(f"{v:16.2f}" if isinstance(v, (float, np.floating))
                           else f"{v!s:>16s}")
            print("  " + " | ".join(row))

    # decode a dictionary-encoded column back to strings
    r1, _ = B.run_local(QUERIES[1], db)
    flags = db.dicts["l_returnflag"][r1["l_returnflag"].astype(int)]
    print("\nQ1 return flags decoded:", list(flags))


if __name__ == "__main__":
    main()

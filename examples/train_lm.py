"""End-to-end training driver: train a small LM for a few hundred steps with
checkpoint/restart.

Default is a ~10M-param model sized for this CPU container; ``--full`` trains
the ~100M configuration (same code path, longer wall time).

    PYTHONPATH=src python examples/train_lm.py [--steps 50] [--full] \
        [--arch mistral_nemo_12b] [--grad-compress bf16]
"""
import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.distributed.checkpoint import CheckpointManager
from repro.models import Model
from repro.train import optimizer as optim
from repro.train.trainstep import init_train_state, make_train_step


def synthetic_batch(rng, vocab, batch, seq):
    """Zipfian token stream with local structure (learnable bigrams)."""
    base = rng.zipf(1.5, size=(batch, seq)).clip(1, vocab - 2)
    shifted = np.roll(base, 1, axis=1) + 1
    mix = rng.random((batch, seq)) < 0.5
    tokens = np.where(mix, base, shifted % (vocab - 1)).astype(np.int32)
    return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(tokens)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral_nemo_12b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true", help="~100M params")
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "bf16", "int8_ef"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if args.full:
        cfg = dataclasses.replace(cfg, n_layers=12, d_model=640, n_heads=8,
                                  n_kv_heads=4, head_dim=80, d_ff=1536,
                                  vocab=32064)
    model = Model(cfg, expert_pad=1)
    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params / 1e6:.1f}M "
          f"compress={args.grad_compress}")

    ocfg = optim.AdamWConfig(lr=1e-3, warmup_steps=10,
                             total_steps=args.steps)
    state = init_train_state(model, params, args.grad_compress)
    step_fn = jax.jit(make_train_step(model, ocfg, args.grad_compress))

    mgr = CheckpointManager(args.ckpt_dir, keep_last=2, async_save=True)
    start, restored, _ = mgr.restore_latest({"params": params,
                                             "state": state})
    if start is not None:
        params, state = restored["params"], restored["state"]
        print(f"restored from step {start}")
    start = start or 0

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for step in range(start + 1, start + args.steps + 1):
        batch = synthetic_batch(rng, cfg.vocab, args.batch, args.seq)
        params, state, metrics = step_fn(params, state, batch)
        if step % 10 == 0 or step == start + 1:
            dt = time.perf_counter() - t0
            print(f"step {step:4d}  loss={float(metrics['loss']):.4f}  "
                  f"gnorm={float(metrics['grad_norm']):.3f}  "
                  f"lr={float(metrics['lr']):.2e}  {dt:.1f}s")
        if step % args.ckpt_every == 0:
            mgr.save(step, {"params": params, "state": state},
                     {"loss": float(metrics["loss"])})
    mgr.wait()
    print(f"done; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()

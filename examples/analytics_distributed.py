"""End-to-end distributed analytics driver (the paper's Figure 1b workflow).

Runs the full 22-query TPC-H workload SPMD over 8 (virtual) devices with the
fault-tolerant runner: host-partitioned load (§4.3), capacity-bounded
collective exchanges, re-execution on overflow, per-query exchange stats.

    PYTHONPATH=src python examples/analytics_distributed.py [--sf 0.01]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax

from repro.data import tpch
from repro.distributed.fault import QueryRunner
from repro.queries import QUERIES
from repro.core.compat import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.01)
    ap.add_argument("--queries", type=str, default="")
    args = ap.parse_args()

    n = len(jax.devices())
    mesh = make_mesh((n,), ("data",))
    print(f"devices={n}  scale factor={args.sf}")
    db = tpch.generate(args.sf, seed=7)
    runner = QueryRunner(db, mesh, capacity_factor=2.5)

    qids = ([int(q) for q in args.queries.split(",") if q]
            or sorted(QUERIES))
    total = 0.0
    for qid in qids:
        res = runner.run(QUERIES[qid])
        total += res.wall_s
        nrows = len(next(iter(res.result.values()))) if res.result else 0
        print(f"Q{qid:2d}  {res.wall_s * 1e3:9.1f} ms  rows={nrows:5d}  "
              f"shuffles={res.stats.shuffles} "
              f"broadcasts={res.stats.broadcasts} "
              f"attempts={res.attempts}")
    print(f"\nall {len(qids)} queries: {total:.2f} s "
          f"(includes trace+compile on first run of each)")


if __name__ == "__main__":
    main()

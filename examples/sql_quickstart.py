"""SQL quickstart: an ad-hoc (non-TPC-H) query through the whole stack.

Takes SQL text the repo has never seen, parses it, prints the canonical
form back, lowers + optimizes it into a logical plan, inspects what the
planner derives (exchange counts, placement validation, per-exchange wire
bytes), then runs the SAME compiled query on the NumPy reference backend
and the JAX local backend and checks they agree.

    PYTHONPATH=src python examples/sql_quickstart.py
"""
import numpy as np

from repro.core import backend as B
from repro.core import planner as PL
from repro.data import tpch
from repro.sql import compile_sql, parse
from repro.sql.ast import format_query

SQL = """
select n_name,
       count(*) as suppliers,
       sum(s_acctbal) as total_bal,
       sum(case when s_acctbal < 0.0 then 1.0 else 0.0 end) as in_debt
from supplier
join nation on s_nationkey = n_nationkey
where s_acctbal < 9000.0
group by n_name
order by total_bal desc
limit 5
"""


def main():
    db = tpch.generate(0.01, seed=7)

    print("canonical form (parse -> print round trip):")
    print(format_query(parse(SQL)))
    print()

    q = compile_sql(SQL, name="supplier_balance")
    print("static exchange counts (no execution):", q.static_counts())
    print("placement validation notes:", PL.validate(q.plan, db) or "clean")
    for e in q.static_wire(db):
        print(f"  {e['kind']}: {e['row_wire_bytes']} B/row on the wire "
              f"({e['row_logical_bytes']} B logical, {e['wire']})")

    r_ref, stats = B.run_reference(q, db)
    assert q.static_counts() == stats.counts(), "static != runtime counts"
    r_loc, _ = B.run_local(q, db)

    print("\n top nations by supplier balance (reference backend):")
    for i in range(len(r_ref["n_name"])):
        name = db.dicts["n_name"][int(np.asarray(r_ref["n_name"])[i])]
        print(f"  {name:<16} suppliers={int(np.asarray(r_ref['suppliers'])[i]):>4} "
              f"total_bal={float(np.asarray(r_ref['total_bal'])[i]):>12.2f} "
              f"in_debt={int(np.asarray(r_ref['in_debt'])[i]):>3}")

    for k in r_ref:
        np.testing.assert_allclose(np.asarray(r_loc[k], np.float64),
                                   np.asarray(r_ref[k], np.float64),
                                   rtol=1e-9, err_msg=k)
    print("\nreference == local: OK")


if __name__ == "__main__":
    main()

"""The three group_aggregate paths on one table — executable documentation.

The engine picks a grouped-aggregation path per group-by (see
docs/ARCHITECTURE.md §3 and the README path table):

  sort    no hints needed            1 HLO sort
  direct  provable key_bits          0 sorts (packed key IS the group id)
  hash    claimed groups_hint        0 sorts (trace-time device dictionary)

This script runs all three on the same table, proves they agree row for row,
and prints the HLO ``sort`` count each one compiles to — then shows the same
choice being made by the planner on real TPC-H plans (Q12's dictionary keys
-> direct; Q13's data-dependent orders-per-customer histogram -> hash).

    PYTHONPATH=src python examples/groupby_paths.py
"""
import numpy as np

import jax

from repro.core import relational as R
from repro.core.table import from_numpy, to_numpy
from repro.data import tpch
from repro.distributed.hlo_analysis import op_histogram
from repro.queries import QUERIES

AGGS = [("total", "sum", "v"), ("rows", "count", None),
        ("lo", "min", "v"), ("hi", "max", "v")]


def hlo_sorts(fn, *args) -> int:
    hlo = jax.jit(fn).lower(*args).compile().as_text()
    return op_histogram(hlo, ops=("sort",))["sort"]


def main():
    rng = np.random.default_rng(7)
    n = 1000
    # keys drawn from a WIDE, data-dependent domain: the value range proves
    # nothing (up to 2^40), but the caller knows there are few distinct keys
    domain = rng.integers(0, 1 << 40, 64).astype(np.int64)
    keys = domain[rng.integers(0, 64, n)]
    vals = rng.normal(size=n)
    t = from_numpy({"k": keys, "v": vals}, capacity=1024)

    runs = {
        # sort: always available, pays ONE stable argsort
        "sort": lambda t: R.group_aggregate(t, ["k"], AGGS, method="sort"),
        # direct: needs provable per-column bit widths -- here the honest
        # claim is 40 bits, far past DIRECT_AGG_BITS_MAX, so to show the path
        # we remap keys onto a provable 6-bit domain first
        "direct": None,                       # filled below (remapped table)
        # hash: needs only a distinct-group bound; keys stay 40-bit
        "hash": lambda t: R.group_aggregate(t, ["k"], AGGS, method="hash",
                                            groups_hint=64,
                                            return_overflow=True)[0],
    }
    remap = {int(k): i for i, k in enumerate(sorted(domain.tolist()))}
    t6 = from_numpy({"k": np.array([remap[int(k)] for k in keys],
                                   dtype=np.int64),
                     "v": vals}, capacity=1024)
    runs["direct"] = lambda t: R.group_aggregate(t, ["k"], AGGS,
                                                 key_bits=[6],
                                                 method="direct")

    results, sorts = {}, {}
    for name, fn in runs.items():
        arg = t6 if name == "direct" else t
        results[name] = to_numpy(fn(arg))
        sorts[name] = hlo_sorts(fn, arg)

    print(f"{'path':8s} {'HLO sorts':>9s} {'groups':>7s} {'sum(total)':>12s}")
    for name in ("sort", "direct", "hash"):
        r = results[name]
        print(f"{name:8s} {sorts[name]:9d} {len(r['rows']):7d} "
              f"{r['total'].sum():12.4f}")

    # hash == sort byte for byte (same 40-bit keys, ascending group order)
    for c in ("total", "rows", "lo", "hi"):
        np.testing.assert_array_equal(results["hash"][c], results["sort"][c])
    # direct agrees on the remapped domain (same rows per group)
    np.testing.assert_array_equal(results["direct"]["rows"],
                                  results["sort"]["rows"])
    print("hash == sort byte-identical; direct agrees on the remapped keys\n")

    # the planner makes the same choice from statistics + claims:
    db = tpch.generate(0.01, seed=7)
    for qid in (12, 13):
        print(QUERIES[qid].explain(db))


if __name__ == "__main__":
    main()
